//! Simulated-systems clock: turns the coordinator's per-client byte ledgers
//! into round wall-time.
//!
//! The real coordinator measures host wall time (`RoundRecord::wall_ms`,
//! the sum of the recorder's plan→close phase spans),
//! which says nothing about deployed round latency: there, a round ends when
//! the server decides it has heard from enough clients. The [`SimClock`]
//! models per-client `download + compute + upload` time from the client's
//! [`DeviceProfile`](crate::scheduler::DeviceProfile); the scheduler sorts
//! those timings into [`CompletionEvent`]s (per-client completion order) and
//! the round engine picks the *close* point — the straggler under a
//! synchronous barrier, the goal-count-th completion under over-selection or
//! buffered aggregation — plus a fixed server-side overhead per round.
//! Clients that drop after fetching spend their download time but never
//! report, so they do not gate the round (the server's timeout is folded
//! into the overhead term).

use crate::scheduler::DeviceProfile;

/// Per-round server-side overhead (cohort assembly, aggregation, model
/// update), seconds.
pub const ROUND_OVERHEAD_S: f64 = 1.0;

/// One client's simulated round timing.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientTiming {
    pub download_s: f64,
    pub compute_s: f64,
    pub upload_s: f64,
}

impl ClientTiming {
    pub fn total_s(&self) -> f64 {
        self.download_s + self.compute_s + self.upload_s
    }
}

/// One client reporting back to the server, as an event on the simulated
/// timeline. Produced in completion order (ties broken by cohort slot) by
/// [`crate::scheduler::Scheduler::events`]; consumed by the round engine's
/// aggregation modes. Dropped clients never report and emit no event.
#[derive(Clone, Copy, Debug)]
pub struct CompletionEvent {
    /// Cohort slot (index into the round plan).
    pub slot: usize,
    /// Train-client index.
    pub client: usize,
    /// Fleet tier of the client's device.
    pub tier: usize,
    /// Completion time relative to round start, seconds.
    pub at_s: f64,
    /// The download/compute/upload breakdown behind `at_s`.
    pub timing: ClientTiming,
}

/// Accumulates simulated time across rounds.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    now_s: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    /// Simulated seconds elapsed since the start of training.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Model one client's round: `down_bytes` over its downlink,
    /// `compute_units` (slice-floats × local examples) through its compute
    /// throughput, `up_bytes` over its uplink.
    pub fn client_timing(
        profile: &DeviceProfile,
        down_bytes: u64,
        up_bytes: u64,
        compute_units: f64,
    ) -> ClientTiming {
        ClientTiming {
            download_s: down_bytes as f64 / profile.down_bps.max(1.0),
            compute_s: compute_units / profile.flops.max(1.0),
            upload_s: up_bytes as f64 / profile.up_bps.max(1.0),
        }
    }

    /// End the round: its duration is the straggler's total time (0 if the
    /// whole cohort dropped) plus the fixed overhead. Advances the clock and
    /// returns the round duration.
    pub fn advance_round(&mut self, completing_times_s: impl IntoIterator<Item = f64>) -> f64 {
        let straggler = completing_times_s
            .into_iter()
            .fold(0.0f64, |acc, t| acc.max(t));
        self.advance_round_to(straggler)
    }

    /// End the round at an arbitrary close point (relative to round start):
    /// the round engine passes the goal-count-th completion under
    /// over-selection / buffered aggregation, or the straggler under the
    /// synchronous barrier. Advances the clock and returns the round
    /// duration (`close_s` + fixed overhead).
    pub fn advance_round_to(&mut self, close_s: f64) -> f64 {
        let round_s = close_s.max(0.0) + ROUND_OVERHEAD_S;
        self.now_s += round_s;
        round_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(down: f64, up: f64, flops: f64) -> DeviceProfile {
        DeviceProfile {
            tier: 0,
            down_bps: down,
            up_bps: up,
            flops,
            mem_frac: 1.0,
            avail_offset: 0,
            avail_period: 0,
            avail_duty: 1.0,
            hazard: 0.0,
        }
    }

    #[test]
    fn timing_is_bytes_over_bandwidth() {
        let p = profile(1e6, 0.5e6, 1e9);
        let t = SimClock::client_timing(&p, 2_000_000, 500_000, 2e9);
        assert!((t.download_s - 2.0).abs() < 1e-9);
        assert!((t.upload_s - 1.0).abs() < 1e-9);
        assert!((t.compute_s - 2.0).abs() < 1e-9);
        assert!((t.total_s() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn round_time_is_the_straggler_plus_overhead() {
        let mut clock = SimClock::new();
        let dt = clock.advance_round([1.0, 7.5, 3.0]);
        assert!((dt - (7.5 + ROUND_OVERHEAD_S)).abs() < 1e-9);
        assert!((clock.now_s() - dt).abs() < 1e-9);
        // an all-dropped round still costs the overhead
        let dt2 = clock.advance_round([]);
        assert!((dt2 - ROUND_OVERHEAD_S).abs() < 1e-9);
        assert!((clock.now_s() - dt - dt2).abs() < 1e-9);
    }

    #[test]
    fn advance_round_to_matches_the_straggler_form() {
        let mut a = SimClock::new();
        let mut b = SimClock::new();
        let da = a.advance_round([1.0, 7.5, 3.0]);
        let db = b.advance_round_to(7.5);
        assert_eq!(da.to_bits(), db.to_bits());
        assert_eq!(a.now_s().to_bits(), b.now_s().to_bits());
        // an early close is cheaper than the barrier
        let early = b.advance_round_to(3.0);
        assert!(early < da);
        // negative close (degenerate) still costs the overhead
        assert!((SimClock::new().advance_round_to(-1.0) - ROUND_OVERHEAD_S).abs() < 1e-12);
    }

    #[test]
    fn slower_devices_take_longer() {
        let fast = profile(25e6, 10e6, 1e10);
        let slow = profile(2e6, 0.5e6, 5e8);
        let (d, u, c) = (400_000, 100_000, 1e8);
        assert!(
            SimClock::client_timing(&slow, d, u, c).total_s()
                > SimClock::client_timing(&fast, d, u, c).total_s()
        );
    }
}

//! Dense f32 matrix/vector ops for the native engine (row-major layout).
//!
//! These mirror the JAX math exactly (same reduction order per row where it
//! matters for the parity tests' tolerances) and are the only linear algebra
//! the coordinator itself needs — the heavy path goes through PJRT.

/// C[m,n] = A[m,k] @ B[k,n] (row-major). `c` is overwritten.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(c.len(), m * n, "C size");
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // sparse BOW rows are mostly zero
            }
            let brow = &b[l * n..(l + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// C[m,n] += alpha * A^T[m,k']... specifically: C[k,n] += alpha * A[m,k]^T @ B[m,n].
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, alpha: f32) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[l * n..(l + 1) * n];
            let f = alpha * av;
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += f * bv;
            }
        }
    }
}

/// C[m,k] = A[m,n] @ B[k,n]^T.
pub fn matmul_b_t(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for j in 0..k {
            let brow = &b[j * n..(j + 1) * n];
            let mut acc = 0.0f32;
            for l in 0..n {
                acc += arow[l] * brow[l];
            }
            c[i * k + j] = acc;
        }
    }
}

/// y += x elementwise.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x.iter()) {
        *a += b;
    }
}

/// y -= alpha * x elementwise.
pub fn axpy_neg(y: &mut [f32], x: &[f32], alpha: f32) {
    assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x.iter()) {
        *a -= alpha * b;
    }
}

/// In-place ReLU; returns a 0/1 activation mask for the backward pass.
pub fn relu_inplace(x: &mut [f32]) -> Vec<f32> {
    let mut mask = vec![0.0f32; x.len()];
    for (v, m) in x.iter_mut().zip(mask.iter_mut()) {
        if *v > 0.0 {
            *m = 1.0;
        } else {
            *v = 0.0;
        }
    }
    mask
}

/// Row-wise log-softmax over an [m, n] matrix, in place.
pub fn log_softmax_rows(x: &mut [f32], m: usize, n: usize) {
    assert_eq!(x.len(), m * n);
    for i in 0..m {
        let row = &mut x[i * n..(i + 1) * n];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut lse = 0.0f32;
        for v in row.iter() {
            lse += (v - max).exp();
        }
        let lse = lse.ln() + max;
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
}

/// Numerically-stable sigmoid.
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Stable elementwise BCE-with-logits: max(z,0) - z*y + log1p(exp(-|z|)).
pub fn bce_with_logits(z: f32, y: f32) -> f32 {
    z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()
}

/// Indices of the k largest values (ties broken by lower index first).
pub fn top_k_indices(x: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    let k = k.min(x.len());
    idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
        x[b].partial_cmp(&x[a]).unwrap().then(a.cmp(&b))
    });
    let mut top = idx[..k].to_vec();
    top.sort_by(|&a, &b| x[b].partial_cmp(&x[a]).unwrap().then(a.cmp(&b)));
    top
}

/// L2 norm.
pub fn l2(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = same
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 0.0, 0.0, 1.0];
        let mut c = [0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, a);
        let mut c2 = [0.0; 4];
        matmul_b_t(&a, &b, &mut c2, 2, 2, 2);
        assert_eq!(c2, a);
    }

    #[test]
    fn matmul_at_b_is_transpose_product() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3,2]
        let b = [1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 3.0, 3.0, 3.0]; // [3,3]
        let mut c = vec![0.0; 2 * 3];
        matmul_at_b(&a, &b, &mut c, 3, 2, 3, 1.0);
        // A^T @ B: row0 = [1,3,5]·cols => [1*1+3*2+5*3, ...] = [22,22,22]
        assert_eq!(&c[..3], &[22.0, 22.0, 22.0]);
        assert_eq!(&c[3..], &[28.0, 28.0, 28.0]);
    }

    #[test]
    fn log_softmax_rows_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        log_softmax_rows(&mut x, 2, 3);
        for i in 0..2 {
            let s: f32 = x[i * 3..(i + 1) * 3].iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn top_k_orders_descending() {
        let x = [0.1, 5.0, 3.0, 4.0, 2.0];
        assert_eq!(top_k_indices(&x, 3), vec![1, 3, 2]);
        assert_eq!(top_k_indices(&x, 10).len(), 5);
    }

    #[test]
    fn bce_matches_naive_in_stable_region() {
        for &(z, y) in &[(0.3f32, 1.0f32), (-0.7, 0.0), (2.0, 1.0)] {
            let p = sigmoid(z);
            let naive = -(y * p.ln() + (1.0 - y) * (1.0 - p).ln());
            assert!((bce_with_logits(z, y) - naive).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_mask() {
        let mut x = vec![-1.0, 2.0, 0.0, 3.0];
        let m = relu_inplace(&mut x);
        assert_eq!(x, vec![0.0, 2.0, 0.0, 3.0]);
        assert_eq!(m, vec![0.0, 1.0, 0.0, 1.0]);
    }
}

//! Minimal numeric substrate: deterministic RNG and dense f32 ops.
//!
//! Everything the native engine and the synthetic data generators need,
//! without pulling in an external linear-algebra dependency. Matrices are
//! row-major `Vec<f32>` with explicit dimensions, matching the layouts the
//! AOT artifacts use.

pub mod ops;
pub mod rng;

pub use ops::*;
pub use rng::Rng;

//! Deterministic PCG32 RNG plus the samplers the synthetic datasets need.
//!
//! Determinism matters here the way it does in the paper's experiments
//! (§5.1): trials vary the seed, but *within* a trial two algorithms must see
//! the same sequence of sampled clients so that variance across algorithms is
//! controlled. A self-contained PCG keeps runs reproducible across platforms.

/// PCG32 (Melissa O'Neill's PCG-XSH-RR 64/32).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create from a seed and a stream id (distinct streams are independent).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut r = Rng {
            state: 0,
            inc: (stream << 1) | 1,
        };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    /// Derive a child RNG; used to give each client / round its own stream.
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15), salt | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method without bias correction is fine for simulation use;
        // use 64-bit multiply to keep bias negligible for any realistic n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Log-normal with the given log-space mean/std.
    pub fn lognormal(&mut self, mu: f32, sigma: f32) -> f32 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct items from `0..n` (k <= n), order random.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 >= n {
            // dense path: shuffle a full index vector
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // sparse path: rejection with a hash set
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Symmetric Dirichlet(alpha) of dimension k (via Gamma(alpha) marginals,
    /// Marsaglia-Tsang for alpha >= 1, boost trick otherwise).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in &mut g {
            *x /= s;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }
}

/// Precomputed Zipf(s) sampler over `0..n` (rank 0 is the most frequent).
///
/// Global word frequencies in the Stack Overflow corpus are famously
/// Zipf-like; this is the backbone of the synthetic BOW/text generators
/// (DESIGN.md §4).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of rank r.
    pub fn pmf(&self, r: usize) -> f64 {
        if r == 0 {
            self.cdf[0]
        } else {
            self.cdf[r] - self.cdf[r - 1]
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42, 1);
        let mut b = Rng::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Rng::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 5, "streams must differ, {same} collisions");
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7, 0);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(17);
            assert!(n < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3, 0);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_without_replacement_is_distinct_and_complete() {
        let mut r = Rng::new(5, 0);
        for &(n, k) in &[(10, 10), (100, 5), (50, 40)] {
            let s = r.sample_without_replacement(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let z = Zipf::new(1000, 1.07);
        let mut r = Rng::new(11, 0);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[200]);
        assert!(z.pmf(0) > z.pmf(5));
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(13, 0);
        for &a in &[0.1, 0.5, 1.0, 5.0] {
            let d = r.dirichlet(a, 8);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17, 0);
        let w = [0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.categorical(&w), 1);
        }
    }
}

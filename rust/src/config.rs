//! Experiment/training configuration: the single description of a training
//! run that the [`crate::coordinator::Trainer`] consumes, with validation
//! and the canonical per-figure defaults.

use crate::aggregation::AggMode;
use crate::cache::EvictPolicy;
use crate::coordinator::AggregationMode;
use crate::data::{bow::BowConfig, images::ImageConfig, text::TextConfig};
use crate::error::{Error, Result};
use crate::exec::ExecMode;
use crate::fedselect::{KeyPolicy, SliceImpl};
use crate::fleet::ScenarioConfig;
use crate::model::ModelArch;
use crate::obs::{ObsConfig, TraceFormat};
use crate::optim::ServerOpt;
use crate::scheduler::{FleetKind, SchedPolicy};

/// Which dataset generator feeds the run.
#[derive(Clone, Debug)]
pub enum DatasetConfig {
    Bow(BowConfig),
    Image(ImageConfig),
    Text(TextConfig),
}

/// Engine selection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust mirror (logreg / MLP families only).
    Native,
    /// AOT artifacts through PJRT; the directory holds manifest.json.
    Pjrt { artifacts_dir: String },
}

impl EngineKind {
    pub fn pjrt_default() -> Self {
        EngineKind::Pjrt {
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

/// Evaluation schedule.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Evaluate every `every` rounds (0 = only at the end).
    pub every: usize,
    /// Cap on pooled eval examples (keeps eval cost bounded).
    pub max_examples: usize,
    /// Use validation split when available (else test).
    pub use_val: bool,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            every: 10,
            max_examples: 2048,
            use_val: false,
        }
    }
}

/// Full description of one federated training run (Algorithm 2).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub arch: ModelArch,
    pub dataset: DatasetConfig,
    pub rounds: usize,
    /// Clients sampled per round (the paper uses 50).
    pub cohort: usize,
    /// One key policy per keyspace of the arch.
    pub policies: Vec<KeyPolicy>,
    pub slice_impl: SliceImpl,
    /// Threads slicing the cohort through the round session (1 = serial;
    /// results are byte-identical at any thread count). Only meaningful
    /// with `exec_workers == 1`: the pipelined executor fetches inside each
    /// per-slot task instead of as one batched phase.
    pub fetch_threads: usize,
    /// Merge-order contract of the pipelined round executor
    /// ([`crate::exec`]): `strict` (default) merges in cohort order and is
    /// byte-identical to the legacy sequential round at any worker count;
    /// `fast` merges in simulated completion order over the key-striped
    /// [`crate::aggregation::ShardedAccumulator`].
    pub exec: ExecMode,
    /// Worker threads draining per-slot round tasks (fetch → hazard →
    /// local-train); 1 = inline on the caller thread (the legacy wall-clock
    /// shape). Values > 1 require the native engine — the PJRT runtime is
    /// exclusive (`&mut`) and cannot run cohort slots concurrently.
    pub exec_workers: usize,
    /// Key-range shards of the fast-mode accumulator (0 = auto: match
    /// `exec_workers`). Strict mode always uses the sequential
    /// [`crate::aggregation::SparseAccumulator`] for bit-exact legacy
    /// float-add order.
    pub agg_shards: usize,
    pub agg: AggMode,
    /// When the round's aggregation *closes*: synchronous barrier (default,
    /// byte-identical to the pre-engine coordinator), over-selection, or
    /// FedBuff-style buffered asynchrony. See
    /// [`crate::coordinator::engine`].
    pub agg_mode: AggregationMode,
    /// Route aggregation through the secure-aggregation simulation. Without
    /// `secure_committee` this is the whole-cohort float-mask protocol and
    /// requires the synchronous barrier (pairwise masks only cancel when
    /// every submitter lands in the same close group).
    pub secure_agg: bool,
    /// Key pairwise masks per *close group* instead of over the whole
    /// cohort: when an `over-select` / `buffered` close fires, the members
    /// that merge together are re-keyed as a fixed-point committee
    /// (committee id = run seed ⊕ close ordinal — the per-run seed, NOT the
    /// per-round one, which already contains the round and would cancel the
    /// ordinal — one committee per
    /// staleness class), stragglers/discards take the per-committee mask
    /// reconstruction path, and staleness weights apply to unmasked
    /// committee sums — which is what lets `secure_agg` compose with every
    /// aggregation mode. See `crate::aggregation::SecAggCommittee`.
    pub secure_committee: bool,
    /// Committee size floor (0 = off), counted over *submitters* —
    /// reconstruction-path dropouts add nothing to the unmasked sum, so
    /// they don't enlarge the anonymity set. A class whose committee would
    /// fall below the floor is coalesced with a neighboring class at the
    /// close (server-side weight splitting — see
    /// [`crate::coordinator::engine`]), since a single-submitter committee
    /// hides nothing. Requires `secure_committee`.
    pub min_committee: usize,
    /// Merge-deferral variant of the committee floor (`--committee-defer`):
    /// instead of coalescing a below-floor staleness class into a neighbor
    /// (server-side weight splitting), hold its landed updates in flight
    /// until enough same-class members land — bounded by the buffered
    /// mode's `max_staleness`, past which they merge (or age out)
    /// regardless. Requires `min_committee > 0` and buffered aggregation
    /// (the only mode with an in-flight pool to defer into).
    pub committee_defer: bool,
    /// Cross-round on-device slice cache ([`crate::cache`]): clients keep
    /// downloaded pieces across rounds and refetch only what the
    /// aggregator has written since. Requires a server optimizer for which
    /// untouched coordinates are a fixed point (fedavg / fedadagrad) and is
    /// incompatible with whole-cohort float-mask secure aggregation (mask
    /// rounding residue writes every coordinate; committees are exact and
    /// compose).
    pub cache: bool,
    /// Per-client cache budget as a fraction of the device's memory cap
    /// (`mem_frac × server bytes`); in (0, 1].
    pub cache_budget_frac: f64,
    /// Cache eviction policy (`lru` / `lfu` / `version-distance`).
    pub cache_evict: EvictPolicy,
    /// Bound on cached-version-metadata age in rounds before a forced
    /// refresh (0 = unbounded). See the stale-read discussion in
    /// [`crate::cache`].
    pub max_stale_rounds: usize,
    pub server_opt: ServerOpt,
    pub client_lr: f32,
    /// Device-population model the cohort scheduler draws from.
    pub fleet: FleetKind,
    /// Cohort-selection policy (`uniform` reproduces pre-scheduler behavior
    /// byte-for-byte at the same seed).
    pub sched_policy: SchedPolicy,
    /// Memory cap of the lowest fleet tier, as a fraction of the full server
    /// model (what `MemoryCapped` clamps select budgets against).
    pub mem_cap_frac: f64,
    /// **Deprecated**: scalar post-fetch dropout probability. Kept for
    /// compatibility; the scheduler applies it as a fleet-wide failure
    /// hazard floor (a `flaky-edge`-style hazard on every profile). Prefer
    /// `fleet: FleetKind::FlakyEdge`.
    pub dropout_rate: f32,
    /// Simulated fleet size; `0` (the default) sizes the fleet to the
    /// dataset's train clients — the legacy, byte-identical path. Larger
    /// fleets select over the full population (profiles are lazy, so 10M
    /// clients cost nothing until touched) and map each fleet id onto a
    /// dataset client modulo the train count at fetch time.
    pub fleet_size: usize,
    /// Churn / regional-outage / availability-wave scenario processes plus
    /// the optional sim-time horizon. All off by default — the bit-exact
    /// legacy eligibility path.
    pub scenario: ScenarioConfig,
    pub eval: EvalConfig,
    pub engine: EngineKind,
    pub seed: u64,
    /// Telemetry: log level, trace sink path, and trace encoding
    /// ([`crate::obs`]). The default is the zero-cost null sink.
    pub obs: ObsConfig,
}

impl TrainConfig {
    /// Canonical §5.2-style run: logreg tag prediction with structured keys,
    /// FedAdagrad, native engine (artifact-free).
    pub fn logreg_default(vocab: usize, m: usize) -> Self {
        TrainConfig {
            arch: ModelArch::logreg(vocab),
            dataset: DatasetConfig::Bow(BowConfig::new(vocab, 50)),
            rounds: 30,
            cohort: 50,
            policies: vec![KeyPolicy::TopFreq { m }],
            slice_impl: SliceImpl::PregenCdn,
            fetch_threads: 1,
            exec: ExecMode::Strict,
            exec_workers: 1,
            agg_shards: 0,
            agg: AggMode::CohortMean,
            agg_mode: AggregationMode::Synchronous,
            secure_agg: false,
            secure_committee: false,
            min_committee: 0,
            committee_defer: false,
            cache: false,
            cache_budget_frac: 0.5,
            cache_evict: EvictPolicy::Lru,
            max_stale_rounds: 0,
            server_opt: ServerOpt::fedadagrad(0.1),
            client_lr: 0.5,
            fleet: FleetKind::Uniform,
            sched_policy: SchedPolicy::Uniform,
            mem_cap_frac: 0.25,
            dropout_rate: 0.0,
            fleet_size: 0,
            scenario: ScenarioConfig::default(),
            eval: EvalConfig::default(),
            engine: EngineKind::Native,
            seed: 7,
            obs: ObsConfig::default(),
        }
    }

    /// §5.3-style run: MLP with random keys, FedAvg.
    pub fn mlp_default(m: usize) -> Self {
        TrainConfig {
            arch: ModelArch::mlp2nn(),
            dataset: DatasetConfig::Image(ImageConfig::new(62)),
            rounds: 40,
            cohort: 50,
            policies: vec![KeyPolicy::RandomGlobal { m }],
            slice_impl: SliceImpl::PregenCdn,
            fetch_threads: 1,
            exec: ExecMode::Strict,
            exec_workers: 1,
            agg_shards: 0,
            agg: AggMode::CohortMean,
            agg_mode: AggregationMode::Synchronous,
            secure_agg: false,
            secure_committee: false,
            min_committee: 0,
            committee_defer: false,
            cache: false,
            cache_budget_frac: 0.5,
            cache_evict: EvictPolicy::Lru,
            max_stale_rounds: 0,
            server_opt: ServerOpt::fedavg(1.0),
            client_lr: 0.05,
            fleet: FleetKind::Uniform,
            sched_policy: SchedPolicy::Uniform,
            mem_cap_frac: 0.25,
            dropout_rate: 0.0,
            fleet_size: 0,
            scenario: ScenarioConfig::default(),
            eval: EvalConfig::default(),
            engine: EngineKind::Native,
            seed: 11,
            obs: ObsConfig::default(),
        }
    }

    /// §5.3-style run: CNN with random filter keys (PJRT required).
    pub fn cnn_default(m: usize) -> Self {
        TrainConfig {
            arch: ModelArch::cnn(),
            dataset: DatasetConfig::Image(ImageConfig::new(62)),
            rounds: 30,
            cohort: 20,
            policies: vec![KeyPolicy::RandomGlobal { m }],
            slice_impl: SliceImpl::PregenCdn,
            fetch_threads: 1,
            exec: ExecMode::Strict,
            exec_workers: 1,
            agg_shards: 0,
            agg: AggMode::CohortMean,
            agg_mode: AggregationMode::Synchronous,
            secure_agg: false,
            secure_committee: false,
            min_committee: 0,
            committee_defer: false,
            cache: false,
            cache_budget_frac: 0.5,
            cache_evict: EvictPolicy::Lru,
            max_stale_rounds: 0,
            server_opt: ServerOpt::fedavg(1.0),
            client_lr: 0.05,
            fleet: FleetKind::Uniform,
            sched_policy: SchedPolicy::Uniform,
            mem_cap_frac: 0.25,
            dropout_rate: 0.0,
            fleet_size: 0,
            scenario: ScenarioConfig::default(),
            eval: EvalConfig::default(),
            engine: EngineKind::pjrt_default(),
            seed: 13,
            obs: ObsConfig::default(),
        }
    }

    /// §5.4-style run: transformer with mixed structured+random keys.
    pub fn transformer_default(mv: usize, dh: usize) -> Self {
        let arch = ModelArch::transformer();
        let (vocab, seq) = match &arch {
            ModelArch::Transformer { shape, .. } => (shape.vocab, shape.seq),
            _ => unreachable!(),
        };
        TrainConfig {
            arch,
            dataset: DatasetConfig::Text(TextConfig::new(vocab, seq)),
            rounds: 30,
            cohort: 20,
            policies: vec![
                KeyPolicy::TopFreq { m: mv },
                KeyPolicy::RandomGlobal { m: dh },
            ],
            slice_impl: SliceImpl::PregenCdn,
            fetch_threads: 1,
            exec: ExecMode::Strict,
            exec_workers: 1,
            agg_shards: 0,
            agg: AggMode::CohortMean,
            agg_mode: AggregationMode::Synchronous,
            secure_agg: false,
            secure_committee: false,
            min_committee: 0,
            committee_defer: false,
            cache: false,
            cache_budget_frac: 0.5,
            cache_evict: EvictPolicy::Lru,
            max_stale_rounds: 0,
            server_opt: ServerOpt::fedadam(0.02),
            client_lr: 0.1,
            fleet: FleetKind::Uniform,
            sched_policy: SchedPolicy::Uniform,
            mem_cap_frac: 0.25,
            dropout_rate: 0.0,
            fleet_size: 0,
            scenario: ScenarioConfig::default(),
            eval: EvalConfig::default(),
            engine: EngineKind::pjrt_default(),
            seed: 23,
            obs: ObsConfig::default(),
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_rounds(mut self, rounds: usize) -> Self {
        self.rounds = rounds;
        self
    }

    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_fetch_threads(mut self, threads: usize) -> Self {
        self.fetch_threads = threads;
        self
    }

    /// Validate cross-field consistency.
    pub fn validate(&self) -> Result<()> {
        if self.rounds == 0 {
            return Err(Error::Config("rounds must be > 0".into()));
        }
        if self.cohort == 0 {
            return Err(Error::Config("cohort must be > 0".into()));
        }
        if self.policies.len() != self.arch.num_keyspaces() {
            return Err(Error::Config(format!(
                "arch has {} keyspaces but {} key policies given",
                self.arch.num_keyspaces(),
                self.policies.len()
            )));
        }
        if !(0.0..1.0).contains(&self.dropout_rate) {
            return Err(Error::Config("dropout_rate must be in [0, 1)".into()));
        }
        match self.agg_mode {
            AggregationMode::Synchronous => {}
            AggregationMode::OverSelect { extra_frac } => {
                if !extra_frac.is_finite() || extra_frac <= 0.0 || extra_frac > 4.0 {
                    return Err(Error::Config(format!(
                        "over-select fraction must be in (0, 4], got {extra_frac}"
                    )));
                }
            }
            AggregationMode::Buffered { goal_count, .. } => {
                if goal_count > self.cohort {
                    return Err(Error::Config(format!(
                        "buffered goal_count {goal_count} exceeds the cohort size {} \
                         (0 = half the cohort)",
                        self.cohort
                    )));
                }
            }
        }
        if self.secure_committee && !self.secure_agg {
            return Err(Error::Config(
                "--secure-committee keys the secure-aggregation masks per close \
                 group and requires --secure-agg"
                    .into(),
            ));
        }
        if self.min_committee > 0 && !self.secure_committee {
            return Err(Error::Config(
                "--min-committee floors the size of close-group SecAgg \
                 committees and requires --secure-committee"
                    .into(),
            ));
        }
        if self.committee_defer {
            if self.min_committee == 0 {
                return Err(Error::Config(
                    "--committee-defer defers below-floor closes and requires \
                     a floor: pass --min-committee N (N > 1)"
                        .into(),
                ));
            }
            if !matches!(self.agg_mode, AggregationMode::Buffered { .. }) {
                return Err(Error::Config(format!(
                    "--committee-defer holds updates in the buffered in-flight \
                     pool and requires --agg-mode buffered, got {}",
                    self.agg_mode
                )));
            }
        }
        if self.cache {
            if !(0.0..=1.0).contains(&self.cache_budget_frac) || self.cache_budget_frac == 0.0 {
                return Err(Error::Config("cache_budget_frac must be in (0, 1]".into()));
            }
            // soundness condition 1: serving a version-fresh piece from the
            // cache is only byte-exact if untouched coordinates never move.
            // Adam/Yogi/momentum keep per-coordinate state that steps rows
            // with a zero update, so a row can change without a version
            // bump.
            match self.server_opt {
                crate::optim::ServerOpt::Sgd { momentum, .. } if momentum == 0.0 => {}
                crate::optim::ServerOpt::Adagrad { .. } => {}
                other => {
                    return Err(Error::Config(format!(
                        "--cache requires a server optimizer for which untouched \
                         coordinates are a fixed point (fedavg without momentum, \
                         fedadagrad); {} moves rows with zero update via its \
                         optimizer state, which would silently serve stale pieces",
                        other.name()
                    )));
                }
            }
            // soundness condition 2: the aggregate must be exactly zero on
            // untouched rows. Whole-cohort float masks cancel only
            // approximately — their rounding residue writes every
            // coordinate. Committee masks cancel exactly in Z_2^64.
            if self.secure_agg && !self.secure_committee {
                return Err(Error::Config(
                    "--cache is incompatible with whole-cohort float-mask secure \
                     aggregation (mask rounding residue writes every coordinate, \
                     invalidating version-fresh cache entries); pass \
                     --secure-committee for exact Z_2^64 cancellation instead"
                        .into(),
                ));
            }
        }
        // The genuinely unsound combination: whole-cohort float masks only
        // cancel when every submitter lands in the same close group, i.e.
        // behind the synchronous barrier. Committees lift this — each close
        // group is re-keyed, so every aggregation mode composes.
        if self.secure_agg
            && !self.secure_committee
            && self.agg_mode != AggregationMode::Synchronous
        {
            return Err(Error::Config(format!(
                "whole-cohort secure aggregation requires --agg-mode sync \
                 (pairwise masks only cancel when everyone lands in one close \
                 group), got {}; pass --secure-committee to re-key masks per \
                 close group instead",
                self.agg_mode
            )));
        }
        if !(0.0..=1.0).contains(&self.mem_cap_frac) || self.mem_cap_frac == 0.0 {
            return Err(Error::Config("mem_cap_frac must be in (0, 1]".into()));
        }
        if self.fleet_size > 0 && self.fleet_size < self.cohort {
            return Err(Error::Config(format!(
                "--fleet-size {} is smaller than the cohort {}",
                self.fleet_size, self.cohort
            )));
        }
        self.scenario.validate()?;
        self.obs.health.validate()?;
        if self.sched_policy == SchedPolicy::MemoryCapped {
            // AllKeys (BROADCAST identity) and FixedPerRound (one shared
            // cohort-wide slice) have no per-client budget to clamp —
            // memory-capped scheduling would silently not cap them.
            if let Some(p) = self.policies.iter().find(|p| {
                matches!(p, KeyPolicy::AllKeys | KeyPolicy::FixedPerRound { .. })
            }) {
                return Err(Error::Config(format!(
                    "sched_policy memory-capped cannot clamp budget-less key \
                     policy {p} (AllKeys / FixedPerRound); use a per-client \
                     key policy or a different scheduler policy"
                )));
            }
        }
        if self.fetch_threads == 0 {
            return Err(Error::Config(
                "fetch_threads must be >= 1 (1 = serial cohort slicing)".into(),
            ));
        }
        if self.exec_workers == 0 {
            return Err(Error::Config(
                "exec_workers must be >= 1 (1 = inline task execution)".into(),
            ));
        }
        if self.exec_workers > 1 && self.engine != EngineKind::Native {
            return Err(Error::Config(
                "--exec-workers > 1 requires --engine native (the PJRT \
                 runtime is exclusive and cannot run cohort slots \
                 concurrently); use --fetch-threads to parallelize slicing \
                 instead"
                    .into(),
            ));
        }
        if self.exec_workers > 1 && self.fetch_threads > 1 {
            return Err(Error::Config(
                "--fetch-threads parallelizes the batched fetch phase, which \
                 the pipelined executor (--exec-workers > 1) replaces with \
                 per-task fetches; pick one"
                    .into(),
            ));
        }
        match (&self.arch, &self.dataset) {
            (ModelArch::Logreg { vocab, tags }, DatasetConfig::Bow(b)) => {
                if b.vocab != *vocab || b.tags != *tags {
                    return Err(Error::Config(format!(
                        "logreg arch (v={vocab},t={tags}) vs bow data (v={},t={})",
                        b.vocab, b.tags
                    )));
                }
            }
            (ModelArch::Mlp { classes, .. }, DatasetConfig::Image(i))
            | (ModelArch::Cnn { classes, .. }, DatasetConfig::Image(i)) => {
                if i.classes != *classes {
                    return Err(Error::Config(format!(
                        "model classes {classes} vs image classes {}",
                        i.classes
                    )));
                }
            }
            (ModelArch::Transformer { shape, .. }, DatasetConfig::Text(t)) => {
                if t.vocab != shape.vocab || t.seq != shape.seq {
                    return Err(Error::Config(format!(
                        "transformer (v={},L={}) vs text data (v={},L={})",
                        shape.vocab, shape.seq, t.vocab, t.seq
                    )));
                }
            }
            (a, d) => {
                return Err(Error::Config(format!(
                    "arch {a:?} incompatible with dataset {}",
                    match d {
                        DatasetConfig::Bow(_) => "bow",
                        DatasetConfig::Image(_) => "image",
                        DatasetConfig::Text(_) => "text",
                    }
                )))
            }
        }
        if self.engine == EngineKind::Native
            && matches!(self.arch, ModelArch::Cnn { .. } | ModelArch::Transformer { .. })
        {
            return Err(Error::Config(
                "native engine supports logreg/MLP only; use --engine pjrt".into(),
            ));
        }
        if let Some(path) = &self.obs.trace_out {
            if path.is_empty() {
                return Err(Error::Config("trace_out path must be non-empty".into()));
            }
        } else if self.obs.trace_format == TraceFormat::Chrome {
            return Err(Error::Config(
                "trace_format chrome requires --trace-out PATH (nothing to \
                 export without a sink)"
                    .into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::logreg_default(512, 64).validate().unwrap();
        TrainConfig::mlp_default(50).validate().unwrap();
        TrainConfig::cnn_default(16).validate().unwrap();
        TrainConfig::transformer_default(256, 128).validate().unwrap();
    }

    #[test]
    fn trace_config_rules() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.obs.trace_out = Some("/tmp/trace.jsonl".to_string());
        assert!(cfg.validate().is_ok());
        cfg.obs.trace_format = TraceFormat::Chrome;
        assert!(cfg.validate().is_ok());
        cfg.obs.trace_out = Some(String::new());
        assert!(cfg.validate().is_err(), "empty trace path rejected");
        cfg.obs.trace_out = None;
        assert!(
            cfg.validate().is_err(),
            "chrome format without a sink rejected"
        );
        cfg.obs.trace_format = TraceFormat::Jsonl;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn mismatched_dataset_rejected() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.dataset = DatasetConfig::Image(ImageConfig::new(62));
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn wrong_policy_count_rejected() {
        let mut cfg = TrainConfig::transformer_default(256, 128);
        cfg.policies.pop();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn zero_fetch_threads_rejected() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.fetch_threads = 0;
        assert!(cfg.validate().is_err());
        assert!(cfg.with_fetch_threads(8).validate().is_ok());
    }

    #[test]
    fn exec_knobs_are_validated() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.exec = ExecMode::Fast;
        cfg.exec_workers = 4;
        assert!(cfg.validate().is_ok());
        cfg.exec_workers = 0;
        assert!(cfg.validate().is_err(), "zero workers rejected");
        // parallel tasks need the shared-reference native engine
        cfg.exec_workers = 4;
        cfg.engine = EngineKind::pjrt_default();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("native"), "{err}");
        cfg.engine = EngineKind::Native;
        // batched-fetch threading conflicts with per-task fetching
        cfg.fetch_threads = 4;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("fetch-threads"), "{err}");
        cfg.fetch_threads = 1;
        assert!(cfg.validate().is_ok());
        // exec_workers == 1 keeps fetch_threads meaningful (legacy shape)
        cfg.exec_workers = 1;
        cfg.fetch_threads = 8;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn memory_capped_rejects_budgetless_key_policies() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.sched_policy = SchedPolicy::MemoryCapped;
        assert!(cfg.validate().is_ok());
        cfg.policies = vec![KeyPolicy::AllKeys];
        assert!(cfg.validate().is_err());
        cfg.policies = vec![KeyPolicy::FixedPerRound { m: 64 }];
        assert!(cfg.validate().is_err());
        cfg.sched_policy = SchedPolicy::Uniform;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn bad_mem_cap_frac_rejected() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.mem_cap_frac = 0.0;
        assert!(cfg.validate().is_err());
        cfg.mem_cap_frac = 1.5;
        assert!(cfg.validate().is_err());
        cfg.mem_cap_frac = 0.1;
        cfg.fleet = FleetKind::Tiered3;
        cfg.sched_policy = SchedPolicy::MemoryCapped;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn native_cnn_rejected() {
        let mut cfg = TrainConfig::cnn_default(16);
        cfg.engine = EngineKind::Native;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn agg_mode_knobs_are_validated() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.agg_mode = AggregationMode::OverSelect { extra_frac: 0.5 };
        assert!(cfg.validate().is_ok());
        cfg.agg_mode = AggregationMode::OverSelect { extra_frac: 0.0 };
        assert!(cfg.validate().is_err());
        cfg.agg_mode = AggregationMode::OverSelect { extra_frac: 9.0 };
        assert!(cfg.validate().is_err());
        cfg.agg_mode = AggregationMode::Buffered {
            goal_count: cfg.cohort,
            max_staleness: 4,
        };
        assert!(cfg.validate().is_ok());
        cfg.agg_mode = AggregationMode::Buffered {
            goal_count: cfg.cohort + 1,
            max_staleness: 4,
        };
        assert!(cfg.validate().is_err());
        // goal 0 = auto (half the cohort)
        cfg.agg_mode = AggregationMode::Buffered {
            goal_count: 0,
            max_staleness: 0,
        };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn cache_requires_fixed_point_server_optimizers() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.cache = true;
        // fedadagrad default: untouched rows are a fixed point
        assert!(cfg.validate().is_ok());
        cfg.server_opt = ServerOpt::fedavg(1.0);
        assert!(cfg.validate().is_ok());
        for bad in [
            ServerOpt::fedadam(0.01),
            ServerOpt::fedyogi(0.01),
            ServerOpt::Sgd {
                lr: 1.0,
                momentum: 0.9,
            },
        ] {
            cfg.server_opt = bad;
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains("fixed point"), "{err}");
        }
        // cache off: any optimizer validates again
        cfg.cache = false;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn cache_rejects_float_mask_secure_agg_and_bad_budgets() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.cache = true;
        cfg.secure_agg = true;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("--secure-committee"), "error names the fix: {err}");
        // committee masks cancel exactly: the combination is sound
        cfg.secure_committee = true;
        assert!(cfg.validate().is_ok());
        cfg.secure_agg = false;
        cfg.secure_committee = false;
        cfg.cache_budget_frac = 0.0;
        assert!(cfg.validate().is_err());
        cfg.cache_budget_frac = 1.5;
        assert!(cfg.validate().is_err());
        cfg.cache_budget_frac = 0.25;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn min_committee_requires_committees() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.min_committee = 2;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("--secure-committee"), "{err}");
        cfg.secure_agg = true;
        cfg.secure_committee = true;
        assert!(cfg.validate().is_ok());
        cfg.min_committee = 0;
        cfg.secure_committee = false;
        cfg.secure_agg = false;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn committee_defer_requires_a_floor_and_buffered_mode() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.committee_defer = true;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("--min-committee"), "{err}");
        cfg.secure_agg = true;
        cfg.secure_committee = true;
        cfg.min_committee = 2;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("buffered"), "{err}");
        cfg.agg_mode = AggregationMode::Buffered {
            goal_count: 0,
            max_staleness: 4,
        };
        assert!(cfg.validate().is_ok());
        // deferral off: the floor alone still validates anywhere
        cfg.committee_defer = false;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn whole_cohort_secure_agg_requires_the_synchronous_barrier() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.secure_agg = true;
        assert!(cfg.validate().is_ok());
        cfg.agg_mode = AggregationMode::Buffered {
            goal_count: 0,
            max_staleness: 4,
        };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("--secure-committee"), "error must name the fix: {err}");
        cfg.agg_mode = AggregationMode::OverSelect { extra_frac: 0.25 };
        assert!(cfg.validate().is_err());
        cfg.secure_agg = false;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn committees_lift_the_sync_only_secure_agg_restriction() {
        let mut cfg = TrainConfig::logreg_default(512, 64);
        cfg.secure_agg = true;
        cfg.secure_committee = true;
        // every aggregation mode composes with committee-keyed masks
        assert!(cfg.validate().is_ok());
        cfg.agg_mode = AggregationMode::Buffered {
            goal_count: 0,
            max_staleness: 4,
        };
        assert!(cfg.validate().is_ok());
        cfg.agg_mode = AggregationMode::OverSelect { extra_frac: 0.25 };
        assert!(cfg.validate().is_ok());
        // ...but committees without secure aggregation are meaningless
        cfg.secure_agg = false;
        assert!(cfg.validate().is_err());
    }
}

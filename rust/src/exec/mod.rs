//! Pipelined round execution: a bounded work-conserving task pool plus the
//! `--exec strict|fast` merge-order contract.
//!
//! # Architecture
//!
//! The coordinator used to run a round as four global phases — plan all,
//! fetch all, compute all cohort slots in order, then merge — so one slow
//! phase bounded the whole round. [`run_tasks`] replaces the middle two
//! phases with *per-slot tasks*: each cohort slot flows as one unit of work
//! (slice/delta fetch → hazard coin → local train → stage) claimed from a
//! shared queue by a bounded worker pool. Claiming is a single
//! `fetch_add` on an atomic cursor: whichever worker is free takes the next
//! slot, which is work-conserving (equivalent to work stealing for a
//! fixed task list — no worker idles while a task is unclaimed).
//!
//! # Determinism
//!
//! Task **outputs are staged slot-indexed** and all side effects (ledger
//! sums, RNG-consuming client events, cache commits) are replayed in
//! cohort order after the pool drains, so the trajectory is byte-identical
//! at any worker count. The only thing wall-clock scheduling is allowed to
//! influence is wall-clock metrics ([`ExecStats`]). The merge-order contract
//! on top of this is [`ExecMode`]:
//!
//! - [`ExecMode::Strict`] (default): updates merge in cohort-slot order at
//!   the close — byte-identical to the legacy sequential round (model bits
//!   and every deterministic `RoundRecord` field), test-enforced at worker
//!   counts {1, 4, 8} across all three slice implementations.
//! - [`ExecMode::Fast`]: updates merge in *simulated completion order*
//!   (the order clients report back on the sim clock) and aggregation runs
//!   on the key-striped [`crate::aggregation::ShardedAccumulator`]. Still
//!   run-to-run deterministic — two same-seed `--exec fast` traces agree on
//!   all sim-time content — but the float-add order differs from strict,
//!   so it is gated on metric-equivalence instead of byte identity.
//!
//! Both modes run the same task pool; `strict` vs `fast` only picks the
//! merge order and the accumulator. `--exec-workers N` sizes the pool
//! (1 = inline on the caller thread, the legacy wall-clock shape).

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Merge-order contract of the pipelined round (`--exec`). See the module
/// docs for the strict-vs-fast determinism story.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Deterministic merge order: slot-indexed staging, merged in cohort
    /// order at close. Byte-identical to the legacy sequential round.
    #[default]
    Strict,
    /// Merge in simulated completion order over the sharded accumulator.
    /// Deterministic run-to-run, not byte-identical to strict.
    Fast,
}

impl ExecMode {
    /// Stable lowercase name (CLI value, trace field, bench labels).
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Strict => "strict",
            ExecMode::Fast => "fast",
        }
    }
}

impl fmt::Display for ExecMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ExecMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "strict" => Ok(ExecMode::Strict),
            "fast" => Ok(ExecMode::Fast),
            other => Err(format!("unknown exec mode '{other}' (expected strict|fast)")),
        }
    }
}

/// Wall-clock observations of one [`run_tasks`] drain. Everything here is
/// host timing — nondeterministic by nature and never allowed to feed back
/// into the trajectory (the same contract as `RoundRecord::wall_ms`).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Slot indices in the order tasks *finished* on the host. Metrics
    /// only; merge order always comes from [`ExecMode`], never from this.
    pub completion_order: Vec<usize>,
    /// Per-worker time spent inside task bodies, ms.
    pub worker_busy_ms: Vec<f64>,
    /// Wall time of the whole drain (first claim to last completion), ms.
    pub elapsed_ms: f64,
    /// Per-slot task body wall time, ms (slot-indexed).
    pub task_wall_ms: Vec<f64>,
}

impl ExecStats {
    /// Pool utilization in [0, 1]: busy worker time over `workers ×
    /// elapsed`. 1.0 for an inline (single-worker) drain by construction.
    pub fn utilization(&self) -> f64 {
        let workers = self.worker_busy_ms.len().max(1) as f64;
        let busy: f64 = self.worker_busy_ms.iter().sum();
        if self.elapsed_ms <= 0.0 {
            return 1.0;
        }
        (busy / (workers * self.elapsed_ms)).min(1.0)
    }
}

/// Drain `inputs` through a pool of `workers` threads: slot `i`'s input is
/// passed to `f(i, input)` exactly once and its output returned at index
/// `i`. Outputs are slot-indexed regardless of which worker ran what, so
/// callers replay side effects deterministically. `workers <= 1` (or a
/// single task) runs inline on the caller thread with no spawns.
pub fn run_tasks<I, O, F>(workers: usize, inputs: Vec<I>, f: F) -> (Vec<O>, ExecStats)
where
    I: Send,
    O: Send,
    F: Fn(usize, I) -> O + Sync,
{
    let n = inputs.len();
    if workers <= 1 || n <= 1 {
        return run_tasks_seq(inputs, f);
    }
    let workers = workers.min(n);
    let slots: Vec<Mutex<Option<I>>> = inputs.into_iter().map(|i| Mutex::new(Some(i))).collect();
    let outs: Vec<Mutex<Option<O>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let walls: Vec<Mutex<f64>> = (0..n).map(|_| Mutex::new(0.0)).collect();
    let next = AtomicUsize::new(0);
    let order = Mutex::new(Vec::with_capacity(n));
    let t0 = Instant::now();
    let worker_busy_ms: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut busy_ms = 0.0f64;
                    loop {
                        let slot = next.fetch_add(1, Ordering::Relaxed);
                        if slot >= n {
                            break;
                        }
                        let input = slots[slot]
                            .lock()
                            .expect("task slot lock")
                            .take()
                            .expect("each task slot is claimed exactly once");
                        let t = Instant::now();
                        let out = f(slot, input);
                        let wall = t.elapsed().as_secs_f64() * 1e3;
                        busy_ms += wall;
                        *walls[slot].lock().expect("task wall lock") = wall;
                        *outs[slot].lock().expect("task out lock") = Some(out);
                        order.lock().expect("completion order lock").push(slot);
                    }
                    busy_ms
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("executor worker panicked"))
            .collect()
    });
    let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
    let outputs = outs
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("task out lock")
                .expect("every task produced an output")
        })
        .collect();
    let stats = ExecStats {
        completion_order: order.into_inner().expect("completion order lock"),
        worker_busy_ms,
        elapsed_ms,
        task_wall_ms: walls
            .into_iter()
            .map(|m| m.into_inner().expect("task wall lock"))
            .collect(),
    };
    (outputs, stats)
}

/// Inline drain on the caller thread. Unlike [`run_tasks`] the closure may
/// be `FnMut` and need not be `Sync`, which is what lets the coordinator
/// route exclusive-engine (PJRT) rounds through the same task plumbing.
pub fn run_tasks_seq<I, O, F>(inputs: Vec<I>, mut f: F) -> (Vec<O>, ExecStats)
where
    F: FnMut(usize, I) -> O,
{
    let n = inputs.len();
    let t0 = Instant::now();
    let mut task_wall_ms = Vec::with_capacity(n);
    let mut outputs = Vec::with_capacity(n);
    for (slot, input) in inputs.into_iter().enumerate() {
        let t = Instant::now();
        outputs.push(f(slot, input));
        task_wall_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = ExecStats {
        completion_order: (0..n).collect(),
        worker_busy_ms: vec![elapsed_ms],
        elapsed_ms,
        task_wall_ms,
    };
    (outputs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_mode_round_trips() {
        for m in [ExecMode::Strict, ExecMode::Fast] {
            assert_eq!(m.to_string().parse::<ExecMode>().unwrap(), m);
        }
        assert_eq!("FAST".parse::<ExecMode>().unwrap(), ExecMode::Fast);
        assert_eq!(" strict ".parse::<ExecMode>().unwrap(), ExecMode::Strict);
        assert!("eager".parse::<ExecMode>().is_err());
        assert_eq!(ExecMode::default(), ExecMode::Strict);
    }

    #[test]
    fn outputs_are_slot_indexed_at_any_worker_count() {
        for workers in [1usize, 2, 4, 8] {
            let inputs: Vec<usize> = (0..23).collect();
            let (outs, stats) = run_tasks(workers, inputs, |slot, x| {
                assert_eq!(slot, x);
                x * 10 + 1
            });
            assert_eq!(outs, (0..23).map(|x| x * 10 + 1).collect::<Vec<_>>());
            let mut order = stats.completion_order.clone();
            order.sort_unstable();
            assert_eq!(order, (0..23).collect::<Vec<_>>(), "workers={workers}");
            assert_eq!(stats.task_wall_ms.len(), 23);
            let expected_workers = if workers <= 1 { 1 } else { workers };
            assert_eq!(stats.worker_busy_ms.len(), expected_workers);
            let u = stats.utilization();
            assert!((0.0..=1.0).contains(&u), "workers={workers} util={u}");
        }
    }

    #[test]
    fn empty_and_single_inputs_run_inline() {
        let (outs, stats) = run_tasks(8, Vec::<u32>::new(), |_, x| x);
        assert!(outs.is_empty());
        assert!(stats.completion_order.is_empty());
        let (outs, stats) = run_tasks(8, vec![7u32], |_, x| x + 1);
        assert_eq!(outs, vec![8]);
        assert_eq!(stats.completion_order, vec![0]);
        assert_eq!(stats.worker_busy_ms.len(), 1, "single task runs inline");
    }

    #[test]
    fn seq_drain_supports_fnmut() {
        let mut seen = Vec::new();
        let (outs, stats) = run_tasks_seq(vec![3u32, 1, 2], |slot, x| {
            seen.push((slot, x));
            x * 2
        });
        assert_eq!(outs, vec![6, 2, 4]);
        assert_eq!(seen, vec![(0, 3), (1, 1), (2, 2)]);
        assert_eq!(stats.completion_order, vec![0, 1, 2]);
    }

    #[test]
    fn parallel_sum_matches_sequential() {
        let inputs: Vec<u64> = (0..200).collect();
        let (a, _) = run_tasks(8, inputs.clone(), |_, x| x * x);
        let (b, _) = run_tasks_seq(inputs, |_, x| x * x);
        assert_eq!(a, b);
    }
}

//! Cross-round client slice cache: versioned server pieces, per-client
//! delta fetch plans, and budgeted on-device cache policies.
//!
//! FedSelect's headline claim is communication efficiency, yet a client
//! that re-selects the same keys next round (the common case for
//! token-keyed and tier-stable selection) re-downloads every piece even
//! when the server never touched those rows. The paper's practicality
//! discussion (§3–4) anticipates clients caching slices across rounds and
//! fetching only what changed; this subsystem supplies the three parts:
//!
//! * [`VersionClock`] — server-side per-`(keyspace, key)` version counters
//!   (plus a segment-level counter for broadcast segments), bumped only for
//!   rows the aggregator actually wrote at a close. A round that never
//!   touches a row never invalidates it.
//! * [`ClientCache`] / [`FleetCaches`] — one budgeted cache per simulated
//!   client (owned by the scheduler's fleet state), holding
//!   `(keyspace, key) -> (version, bytes)` entries under a per-tier byte
//!   budget derived from the client's
//!   [`DeviceProfile`](crate::scheduler::DeviceProfile) memory, with
//!   pluggable eviction ([`EvictPolicy`]) and a `max_stale_rounds` bound on
//!   cached-metadata age.
//! * [`DeltaPlan`](crate::fedselect::DeltaPlan) consumption — before phase
//!   2 the trainer asks [`FleetCaches::plan_for`] which of a client's
//!   pieces are *fresh* (cached at the current server version); the round
//!   session serves those locally (ledgered as client-cache hits, no
//!   downlink bytes) and downloads the rest. After the fetch,
//!   [`FleetCaches::commit`] records the downloads and hits.
//!
//! **Fidelity.** The cache stores piece *metadata* (version + byte size),
//! not the float payload: a fresh entry proves the server has not written
//! those rows since the client fetched them, so the bytes the client holds
//! ARE the server's bytes and serving "from cache" is byte-identical to
//! re-downloading — which is why the simulator can serve the bundle from
//! the store while charging zero wire bytes. This requires two soundness
//! conditions, enforced by [`crate::config::TrainConfig::validate`]:
//! untouched coordinates must be a fixed point of the server optimizer
//! (true for FedAvg-without-momentum and FedAdagrad; false for
//! Adam/Yogi/momentum, whose state moves rows with zero update), and the
//! aggregate must be *exactly* zero on untouched rows (true for plain and
//! committee-keyed secure aggregation; false for whole-cohort float masks,
//! whose rounding residue lands everywhere).
//!
//! **Accounting.** Only downlink payload bytes are saved. Revalidation is
//! charged at full cost: keys still go up (`up_key_bytes` unchanged — the
//! server must see the key+version list to answer "fresh"), and the
//! per-key server work (`psi_evals` / memo hits / `cdn_queries` /
//! `service_us`) is charged as if the piece were served, modeling a
//! not-modified response on the same code path. So between `--cache` on
//! and off, only `down_bytes`, the client-cache hit counters, and the
//! simulated clock (which consumes post-cache down bytes) differ — the
//! model trajectory and every other ledger field are byte-identical under
//! the synchronous barrier, test-enforced in `tests/slice_cache.rs`.
//!
//! **Stale reads.** A fresh cache entry is never stale data — version
//! equality is exact. `max_stale_rounds` bounds something different: how
//! long the client may *trust its cached version metadata* before forcing
//! a refresh (age is measured from the fetch round, not the last hit).
//! This is deliberately the same shape as the buffered round engine's
//! `max_staleness`: both bound the age of client-held state, but buffered
//! staleness discounts *updates computed on old models* (weight
//! `1/sqrt(1+staleness)`), while cache staleness only forces a refetch of
//! provably-identical bytes — it never changes the trajectory, only the
//! byte ledger.

pub mod client;
pub mod version;

pub use client::{BudgetSource, ClientCache, CommitStats, FleetCaches};
pub use version::VersionClock;

/// Pseudo-keyspace id addressing segment-granularity cache entries:
/// `(BROADCAST_SPACE, segment-index)` is a whole model segment, cached by
/// Option 1 (full-model broadcast) for every segment and by Options 2/3
/// for the broadcast-in-full (`Binding::Full`) segments.
pub const BROADCAST_SPACE: usize = usize::MAX;

/// How a [`ClientCache`] chooses a victim when inserting past its byte
/// budget (config-level knob; CLI `--cache-evict`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Evict the least-recently-used entry (oldest `last_used_round`).
    #[default]
    Lru,
    /// Evict the least-frequently-used entry (fewest hits).
    Lfu,
    /// Evict the entry whose version lags the server's furthest (most
    /// likely to be stale and refetched anyway).
    VersionDistance,
}

impl EvictPolicy {
    pub const ALL: [EvictPolicy; 3] =
        [EvictPolicy::Lru, EvictPolicy::Lfu, EvictPolicy::VersionDistance];
}

/// Canonical CLI names; `Display` round-trips with `FromStr`.
impl std::fmt::Display for EvictPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EvictPolicy::Lru => "lru",
            EvictPolicy::Lfu => "lfu",
            EvictPolicy::VersionDistance => "version-distance",
        })
    }
}

impl std::str::FromStr for EvictPolicy {
    type Err = String;
    /// Case-insensitive; accepts the canonical `Display` names plus
    /// underscore/short aliases.
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Ok(EvictPolicy::Lru),
            "lfu" => Ok(EvictPolicy::Lfu),
            "version-distance" | "version_distance" | "vdist" => Ok(EvictPolicy::VersionDistance),
            other => Err(format!(
                "unknown eviction policy {other:?} (want {}, {} or {})",
                EvictPolicy::Lru,
                EvictPolicy::Lfu,
                EvictPolicy::VersionDistance
            )),
        }
    }
}

/// How N concurrent jobs share one device's cache byte budget (multi-tenant
/// coordinator knob; see [`crate::tenancy`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CacheShare {
    /// Each job gets a guaranteed, isolated slice of the device budget
    /// (its weight share of the total): job A's inserts can never evict
    /// job B's entries. A single job's full (1.0) share is exactly the
    /// single-tenant budget.
    #[default]
    Partitioned,
    /// One pooled cache per device, budgeted at the per-job maximum:
    /// jobs contend for bytes and may evict each other's entries
    /// (namespaced addresses keep the *contents* from colliding; only
    /// capacity is shared).
    Contended,
}

/// Canonical CLI names; `Display` round-trips with `FromStr`.
impl std::fmt::Display for CacheShare {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CacheShare::Partitioned => "partitioned",
            CacheShare::Contended => "contended",
        })
    }
}

impl std::str::FromStr for CacheShare {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "partitioned" | "partition" => Ok(CacheShare::Partitioned),
            "contended" | "shared" | "pool" => Ok(CacheShare::Contended),
            other => Err(format!(
                "unknown cache share {other:?} (want {} or {})",
                CacheShare::Partitioned,
                CacheShare::Contended
            )),
        }
    }
}

/// Which cache entries one client's round touches, and how big each is —
/// derived once per run by the trainer from the model's `SelectSpec`, the
/// store layout, and the slice implementation.
#[derive(Clone, Debug)]
pub struct CacheGeometry {
    /// Bytes of one keyed piece, per keyspace.
    pub piece_bytes: Vec<u64>,
    /// Bytes of each model segment (indexed by segment id).
    pub seg_bytes: Vec<u64>,
    /// Segments cached at segment granularity: every segment under Option 1
    /// (the client downloads the whole model), the `Binding::Full` segments
    /// under Options 2/3 (keyed segments travel as per-key pieces there).
    pub cached_segs: Vec<usize>,
    /// Whether keyed pieces are cached per `(keyspace, key)` (false under
    /// Option 1, where keys never leave the device and the wire unit is the
    /// whole segment).
    pub keyed: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_share_display_round_trips() {
        for s in [CacheShare::Partitioned, CacheShare::Contended] {
            assert_eq!(s.to_string().parse::<CacheShare>().unwrap(), s);
        }
        assert_eq!("shared".parse::<CacheShare>().unwrap(), CacheShare::Contended);
        assert!("bogus".parse::<CacheShare>().is_err());
    }

    #[test]
    fn evict_policy_display_round_trips_case_insensitively() {
        for p in EvictPolicy::ALL {
            let shown = p.to_string();
            assert_eq!(shown.parse::<EvictPolicy>().unwrap(), p);
            assert_eq!(shown.to_uppercase().parse::<EvictPolicy>().unwrap(), p);
        }
        assert_eq!(
            "vdist".parse::<EvictPolicy>().unwrap(),
            EvictPolicy::VersionDistance
        );
        let err = "bogus".parse::<EvictPolicy>().unwrap_err();
        assert!(err.contains("lru") && err.contains("version-distance"), "{err}");
    }
}

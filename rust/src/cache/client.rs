//! Budgeted on-device piece caches, one per simulated client.
//!
//! A [`ClientCache`] holds `(keyspace, key) -> (version, bytes)` metadata
//! for the pieces the client downloaded (see the module docs of
//! [`super`] for why metadata suffices for a byte-exact simulation), under
//! a per-client byte budget derived from the device's memory tier.
//! [`FleetCaches`] owns one cache per train client and exposes the two
//! trainer entry points: [`FleetCaches::plan_for`] (pre-fetch, read-only:
//! which pieces are fresh) and [`FleetCaches::commit`] (post-fetch:
//! record hits and downloads, evict past the budget).
//!
//! Everything is deterministic: lookups consume no randomness, commits run
//! in cohort order, and eviction picks its victim by a total order —
//! policy score first, then the entry id — so two runs at the same seed
//! evict identically (test-enforced in `tests/slice_cache.rs`).

use std::collections::HashMap;

use crate::fedselect::DeltaPlan;

use super::{CacheGeometry, EvictPolicy, VersionClock, BROADCAST_SPACE};

/// Cache-entry address: `(keyspace, key)` for keyed pieces,
/// `(BROADCAST_SPACE, segment-index)` for segment-granularity entries.
pub type PieceId = (usize, u32);

#[derive(Clone, Copy, Debug)]
struct Entry {
    /// Server version of the piece when it was downloaded.
    version: u64,
    /// Round the piece was downloaded (refresh resets it; hits do not).
    fetched_round: u64,
    /// Round of the last hit or download (LRU score).
    last_used_round: u64,
    /// Hits plus downloads of this entry (LFU score).
    uses: u64,
    bytes: u64,
}

/// What one client's cache did at a round commit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommitStats {
    /// Cacheable piece lookups this round (hits + misses).
    pub lookups: u64,
    /// Lookups served from the cache (fresh version, within the stale
    /// bound) — these paid no downlink bytes.
    pub hits: u64,
    /// Bytes those hits would have cost on the wire.
    pub hit_bytes: u64,
    /// Entries evicted to fit this round's downloads under the budget.
    pub evictions: u64,
    /// Version-fresh entries refetched only because their age exceeded
    /// `max_stale_rounds`.
    pub stale_refreshes: u64,
}

impl CommitStats {
    pub fn accumulate(&mut self, other: &CommitStats) {
        self.lookups += other.lookups;
        self.hits += other.hits;
        self.hit_bytes += other.hit_bytes;
        self.evictions += other.evictions;
        self.stale_refreshes += other.stale_refreshes;
    }
}

/// One simulated client's piece cache.
///
/// Entries are keyed by `(namespace, piece)` — the namespace is the owning
/// job's id ([`VersionClock::ns`]), 0 for single-tenant runs — so one
/// device's cache can hold pieces of several concurrent jobs without
/// address collisions while every byte still counts against the one
/// shared budget.
#[derive(Clone, Debug)]
pub struct ClientCache {
    budget: u64,
    used: u64,
    entries: HashMap<(u32, PieceId), Entry>,
}

/// How a lookup classified an entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Lookup {
    /// Version matches and the metadata is young enough: serve locally.
    Fresh,
    /// Version matches but the entry is older than `max_stale_rounds`:
    /// forced refresh.
    AgedOut,
    /// Absent, or the server has written the row since it was fetched.
    Miss,
}

impl ClientCache {
    pub fn new(budget: u64) -> Self {
        ClientCache {
            budget,
            used: 0,
            entries: HashMap::new(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Single-tenant lookup (namespace 0) — see [`Self::contains_ns`].
    pub fn contains(&self, id: PieceId) -> bool {
        self.contains_ns(0, id)
    }

    /// Whether the cache holds `id` under tenancy namespace `ns`.
    pub fn contains_ns(&self, ns: u32, id: PieceId) -> bool {
        self.entries.contains_key(&(ns, id))
    }

    fn classify(
        &self,
        ns: u32,
        id: PieceId,
        round: u64,
        max_stale_rounds: usize,
        versions: &VersionClock,
    ) -> Lookup {
        let Some(e) = self.entries.get(&(ns, id)) else {
            return Lookup::Miss;
        };
        if e.version != versions.version_of(id.0, id.1) {
            return Lookup::Miss;
        }
        // age is measured from the download, not the last hit: the knob
        // bounds how long version *metadata* is trusted, and a hit renews
        // nothing the server said
        if max_stale_rounds > 0 && round.saturating_sub(e.fetched_round) > max_stale_rounds as u64
        {
            return Lookup::AgedOut;
        }
        Lookup::Fresh
    }

    /// Evict one entry by `policy`; returns false when the cache is empty.
    /// The victim is the minimum of a total order (policy score, then
    /// `(ns, id)`), so eviction is deterministic regardless of hash-map
    /// iteration order — and identical to the pre-tenancy order whenever
    /// every entry shares one namespace.
    fn evict_one(&mut self, ns: u32, policy: EvictPolicy, versions: &VersionClock) -> bool {
        let victim = self
            .entries
            .iter()
            .map(|(&key, e)| {
                let score = match policy {
                    EvictPolicy::Lru => (e.last_used_round, e.uses),
                    EvictPolicy::Lfu => (e.uses, e.last_used_round),
                    EvictPolicy::VersionDistance => {
                        // most-lagging first: lagging entries are dead weight
                        // (they will miss on their next lookup anyway). Only
                        // the committing job's clock is at hand, so foreign-
                        // namespace entries score distance 0 (preserved over
                        // equally-recent lagging entries of the own job).
                        let dist = if key.0 == ns {
                            versions.version_of(key.1 .0, key.1 .1).saturating_sub(e.version)
                        } else {
                            0
                        };
                        (u64::MAX - dist, e.last_used_round)
                    }
                };
                (score, key)
            })
            .min();
        match victim {
            Some((_, key)) => {
                let e = self.entries.remove(&key).expect("victim exists");
                self.used -= e.bytes;
                true
            }
            None => false,
        }
    }

    fn touch(&mut self, ns: u32, id: PieceId, round: u64) {
        let e = self.entries.get_mut(&(ns, id)).expect("hit entry exists");
        e.last_used_round = round;
        e.uses += 1;
    }

    /// Record a download: insert or refresh the entry at the current server
    /// version, evicting per `policy` until it fits. An entry bigger than
    /// the whole budget is not cached at all. Returns evictions performed.
    fn insert(
        &mut self,
        ns: u32,
        id: PieceId,
        bytes: u64,
        round: u64,
        policy: EvictPolicy,
        versions: &VersionClock,
    ) -> u64 {
        let version = versions.version_of(id.0, id.1);
        if let Some(e) = self.entries.get_mut(&(ns, id)) {
            // refresh in place (piece sizes are fixed per id): the row's
            // popularity survives the refresh
            e.version = version;
            e.fetched_round = round;
            e.last_used_round = round;
            e.uses += 1;
            return 0;
        }
        if bytes > self.budget {
            return 0;
        }
        let mut evictions = 0u64;
        while self.used + bytes > self.budget {
            if !self.evict_one(ns, policy, versions) {
                break;
            }
            evictions += 1;
        }
        self.used += bytes;
        self.entries.insert(
            (ns, id),
            Entry {
                version,
                fetched_round: round,
                last_used_round: round,
                uses: 1,
                bytes,
            },
        );
        evictions
    }
}

/// Where a client's cache budget comes from when its cache is first
/// materialized.
///
/// The eager design carried a `Vec<u64>` of budgets sized to the fleet —
/// O(fleet) bytes before a single client was ever selected. [`Derived`]
/// replaces the table with its closed form (device `mem_frac` × server
/// bytes × the configured cache fraction), computed lazily from the fleet's
/// pure profile function, so a 10M-client fleet carries no budget table at
/// all. [`Table`] remains for explicit per-client budgets (tenancy pooling,
/// tests).
#[derive(Clone, Debug)]
pub enum BudgetSource {
    /// Explicit per-client budgets, indexed by client id.
    Table(Vec<u64>),
    /// `budget(ci) = profile(ci).mem_bytes(server_bytes) × frac`, resolved
    /// by the scheduler (which owns the fleet) at `ensure_cache` time.
    Derived { server_bytes: usize, frac: f64 },
}

/// Budgeted piece caches for the clients that have ever fetched, plus the
/// shared policy knobs — owned by the scheduler's fleet state (the cache is
/// device state, like the profile it is budgeted from). Caches materialize
/// on first use ([`FleetCaches::ensure`]), so resident memory is
/// O(clients ever selected), never O(fleet).
#[derive(Clone, Debug)]
pub struct FleetCaches {
    policy: EvictPolicy,
    max_stale_rounds: usize,
    budget_source: BudgetSource,
    caches: HashMap<usize, ClientCache>,
}

/// Enumerate the cache entries one client round touches, in deterministic
/// order: segment entries first (ascending segment id), then keyed pieces
/// in the client's key order.
fn entries_for<'a>(
    geom: &'a CacheGeometry,
    keys: &'a [Vec<u32>],
) -> impl Iterator<Item = (PieceId, u64)> + 'a {
    let segs = geom
        .cached_segs
        .iter()
        .map(|&s| ((BROADCAST_SPACE, s as u32), geom.seg_bytes[s]));
    let keyed = keys
        .iter()
        .enumerate()
        .filter(|_| geom.keyed)
        .flat_map(|(ks, kk)| kk.iter().map(move |&k| ((ks, k), geom.piece_bytes[ks])));
    segs.chain(keyed)
}

impl FleetCaches {
    /// Explicit per-client budgets (indexed by client id); a client's cache
    /// still materializes only on first use.
    pub fn new(policy: EvictPolicy, max_stale_rounds: usize, budgets: Vec<u64>) -> Self {
        FleetCaches {
            policy,
            max_stale_rounds,
            budget_source: BudgetSource::Table(budgets),
            caches: HashMap::new(),
        }
    }

    /// Budgets derived lazily from the device profiles:
    /// `mem_bytes(server_bytes) × frac` per client, resolved by the
    /// scheduler at [`FleetCaches::ensure`] time — no per-fleet table.
    pub fn derived(
        policy: EvictPolicy,
        max_stale_rounds: usize,
        server_bytes: usize,
        frac: f64,
    ) -> Self {
        FleetCaches {
            policy,
            max_stale_rounds,
            budget_source: BudgetSource::Derived { server_bytes, frac },
            caches: HashMap::new(),
        }
    }

    pub fn policy(&self) -> EvictPolicy {
        self.policy
    }

    pub fn max_stale_rounds(&self) -> usize {
        self.max_stale_rounds
    }

    pub fn budget_source(&self) -> &BudgetSource {
        &self.budget_source
    }

    /// The client's cache, if it has ever been materialized.
    pub fn cache(&self, client: usize) -> Option<&ClientCache> {
        self.caches.get(&client)
    }

    /// Whether `client`'s cache has been materialized.
    pub fn has_cache(&self, client: usize) -> bool {
        self.caches.contains_key(&client)
    }

    /// Number of materialized caches (≤ clients ever selected).
    pub fn clients_cached(&self) -> usize {
        self.caches.len()
    }

    /// Approximate resident bytes of the cache *metadata* store itself
    /// (entries × slot size, not the simulated piece bytes) — the
    /// `fleet.resident_bytes` gauge's cache component.
    pub fn resident_bytes(&self) -> u64 {
        let entry_slot = std::mem::size_of::<(u32, PieceId)>() + std::mem::size_of::<Entry>();
        self.caches
            .values()
            .map(|c| {
                (std::mem::size_of::<usize>()
                    + std::mem::size_of::<ClientCache>()
                    + c.entries.len() * entry_slot) as u64
            })
            .sum()
    }

    /// Materialize `client`'s cache at `budget` if absent (no-op, budget
    /// untouched, when present). The scheduler calls this for every cohort
    /// member before the round's cache traffic.
    pub fn ensure(&mut self, client: usize, budget: u64) {
        self.caches
            .entry(client)
            .or_insert_with(|| ClientCache::new(budget));
    }

    /// The budget table, for [`BudgetSource::Table`] fleets (tenancy pools
    /// its shared budgets through this). Empty for derived budgets — those
    /// are resolved per client via the scheduler's fleet.
    pub fn budgets(&self) -> Vec<u64> {
        match &self.budget_source {
            BudgetSource::Table(t) => t.clone(),
            BudgetSource::Derived { .. } => Vec::new(),
        }
    }

    /// Scale every client's budget by `frac` (clamped at ≥ 0) — the
    /// partitioned cache-share mode gives each job a guaranteed fraction of
    /// the device budget. Intended at setup, before any entry is inserted;
    /// shrinking an occupied cache does not evict retroactively (the next
    /// commit's inserts will).
    pub fn scale_budgets(&mut self, frac: f64) {
        let f = frac.max(0.0);
        match &mut self.budget_source {
            BudgetSource::Table(t) => {
                for b in t.iter_mut() {
                    *b = (*b as f64 * f) as u64;
                }
            }
            BudgetSource::Derived { frac, .. } => *frac *= f,
        }
        for c in self.caches.values_mut() {
            c.budget = (c.budget as f64 * f) as u64;
        }
    }

    /// Pre-fetch: which of this client's pieces are fresh — the session
    /// serves those locally. Read-only; the same classification is re-run
    /// (on the unchanged cache) by [`FleetCaches::commit`].
    pub fn plan_for(
        &self,
        client: usize,
        round: u64,
        keys: &[Vec<u32>],
        geom: &CacheGeometry,
        versions: &VersionClock,
    ) -> DeltaPlan {
        let ns = versions.ns();
        // a never-materialized cache classifies everything as a miss: the
        // empty plan is byte-identical to planning against a fresh cache
        let Some(cache) = self.caches.get(&client) else {
            return DeltaPlan::default();
        };
        let mut plan = DeltaPlan::default();
        for (id, _) in entries_for(geom, keys) {
            if cache.classify(ns, id, round, self.max_stale_rounds, versions) == Lookup::Fresh {
                if id.0 == BROADCAST_SPACE {
                    plan.fresh_segs.insert(id.1 as usize);
                } else {
                    plan.fresh_keys.insert(id);
                }
            }
        }
        plan
    }

    /// Post-fetch: record this client's round against its cache — touch the
    /// hits, insert/refresh the downloads (evicting per policy), and tally
    /// the round's [`CommitStats`]. Must be called with the same
    /// `keys`/`geom`/`versions` the plan was built from, before any
    /// version bump for this round.
    ///
    /// Three ordered passes, not one interleaved loop: every entry is
    /// classified against the *pre-round* cache state first (the exact view
    /// [`FleetCaches::plan_for`] — and hence the session ledger — used; an
    /// interleaved insert could evict a plan-fresh entry before its own
    /// lookup and silently undercount hits), then hits are touched (so this
    /// round's own hits are maximally recent before any eviction runs),
    /// then downloads insert. An insert may still evict an already-served
    /// hit — that is consistent: the bytes were saved this round, the entry
    /// is simply gone next round.
    pub fn commit(
        &mut self,
        client: usize,
        round: u64,
        keys: &[Vec<u32>],
        geom: &CacheGeometry,
        versions: &VersionClock,
    ) -> CommitStats {
        let policy = self.policy;
        let max_stale = self.max_stale_rounds;
        let ns = versions.ns();
        if !self.caches.contains_key(&client) {
            // table budgets resolve here; derived budgets need the fleet,
            // so the scheduler must have called `ensure_cache` first
            let budget = match &self.budget_source {
                BudgetSource::Table(t) => t.get(client).copied().unwrap_or(0),
                BudgetSource::Derived { .. } => {
                    panic!("derived budgets: ensure() must precede commit for client {client}")
                }
            };
            self.ensure(client, budget);
        }
        let cache = self.caches.get_mut(&client).expect("ensured above");
        let mut st = CommitStats::default();
        let classified: Vec<(PieceId, u64, Lookup)> = entries_for(geom, keys)
            .map(|(id, bytes)| (id, bytes, cache.classify(ns, id, round, max_stale, versions)))
            .collect();
        st.lookups = classified.len() as u64;
        for &(id, bytes, lk) in &classified {
            if lk == Lookup::Fresh {
                st.hits += 1;
                st.hit_bytes += bytes;
                cache.touch(ns, id, round);
            }
        }
        for &(id, bytes, lk) in &classified {
            match lk {
                Lookup::Fresh => {}
                Lookup::AgedOut => {
                    st.stale_refreshes += 1;
                    st.evictions += cache.insert(ns, id, bytes, round, policy, versions);
                }
                Lookup::Miss => {
                    st.evictions += cache.insert(ns, id, bytes, round, policy, versions);
                }
            }
        }
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::TouchedKeys;
    use crate::model::ModelArch;

    fn geom() -> CacheGeometry {
        // logreg(8)-shaped: keyed weight rows of 200 B, one Full bias seg
        CacheGeometry {
            piece_bytes: vec![200],
            seg_bytes: vec![1600, 200],
            cached_segs: vec![1],
            keyed: true,
        }
    }

    fn clock() -> VersionClock {
        VersionClock::new(&[8], 2)
    }

    #[test]
    fn fresh_entries_hit_and_save_their_bytes() {
        let mut fc = FleetCaches::new(EvictPolicy::Lru, 0, vec![10_000]);
        let g = geom();
        let vc = clock();
        let keys = vec![vec![1u32, 2, 3]];
        // round 1: cold — everything downloads
        let p1 = fc.plan_for(0, 1, &keys, &g, &vc);
        assert!(p1.is_empty());
        let s1 = fc.commit(0, 1, &keys, &g, &vc);
        assert_eq!((s1.lookups, s1.hits), (4, 0)); // bias seg + 3 keys
        // round 2, nothing written: everything fresh
        let p2 = fc.plan_for(0, 2, &keys, &g, &vc);
        assert_eq!(p2.fresh_keys.len(), 3);
        assert!(p2.fresh_segs.contains(&1));
        let s2 = fc.commit(0, 2, &keys, &g, &vc);
        assert_eq!((s2.hits, s2.hit_bytes), (4, 200 + 3 * 200));
        assert_eq!(s2.evictions, 0);
    }

    #[test]
    fn a_version_bump_invalidates_exactly_the_written_rows() {
        let mut fc = FleetCaches::new(EvictPolicy::Lru, 0, vec![10_000]);
        let g = geom();
        let mut vc = clock();
        let keys = vec![vec![1u32, 2, 3]];
        fc.commit(0, 1, &keys, &g, &vc);
        // round 1's close writes key 2 (and hence both segments)
        let spec = ModelArch::logreg(8).select_spec();
        let mut touched = TouchedKeys::new(1);
        touched.record(&[vec![2]]);
        vc.bump(1, &touched, &spec);
        let p = fc.plan_for(0, 2, &keys, &g, &vc);
        assert!(p.fresh_keys.contains(&(0, 1)) && p.fresh_keys.contains(&(0, 3)));
        assert!(!p.fresh_keys.contains(&(0, 2)), "written row must miss");
        assert!(!p.fresh_segs.contains(&1), "Full segment was written");
    }

    #[test]
    fn max_stale_rounds_forces_refresh_exactly_at_the_boundary() {
        let mut fc = FleetCaches::new(EvictPolicy::Lru, 2, vec![10_000]);
        let g = geom();
        let vc = clock();
        let keys = vec![vec![5u32]];
        fc.commit(0, 1, &keys, &g, &vc);
        // ages 1 and 2 are trusted; hits do not renew the download age
        for round in [2u64, 3] {
            let s = fc.commit(0, round, &keys, &g, &vc);
            assert_eq!(s.hits, 2, "round {round}");
            assert_eq!(s.stale_refreshes, 0, "round {round}");
        }
        // age 3 > max_stale_rounds=2: forced refresh despite a fresh version
        let s4 = fc.commit(0, 4, &keys, &g, &vc);
        assert_eq!(s4.hits, 0);
        assert_eq!(s4.stale_refreshes, 2);
        // the refresh reset the download age: trusted again next round
        let s5 = fc.commit(0, 5, &keys, &g, &vc);
        assert_eq!(s5.hits, 2);
    }

    #[test]
    fn eviction_respects_the_budget_and_the_policy_order() {
        // budget fits the bias segment plus two keyed pieces
        let mut fc = FleetCaches::new(EvictPolicy::Lru, 0, vec![600]);
        let g = geom();
        let vc = clock();
        fc.commit(0, 1, &[vec![1u32, 2]], &g, &vc);
        assert_eq!(fc.cache(0).unwrap().len(), 3);
        assert_eq!(fc.cache(0).unwrap().used_bytes(), 600);
        // key 1 is re-used in round 2; key 3 arrives and must evict key 2
        // (LRU: last used round 1; the seg + key 1 were used in round 2)
        let s = fc.commit(0, 2, &[vec![1u32, 3]], &g, &vc);
        assert_eq!(s.hits, 2);
        assert_eq!(s.evictions, 1);
        assert!(fc.cache(0).unwrap().contains((0, 1)));
        assert!(fc.cache(0).unwrap().contains((0, 3)));
        assert!(!fc.cache(0).unwrap().contains((0, 2)));
        assert!(fc.cache(0).unwrap().used_bytes() <= 600);
    }

    #[test]
    fn commit_classifies_against_the_pre_round_state() {
        // regression: an insert early in the commit walk must not evict a
        // plan-fresh entry before its own lookup — the session already
        // served it as a zero-byte hit, and plan/commit hit agreement is
        // load-bearing (the trainer debug-asserts it)
        let mut fc = FleetCaches::new(EvictPolicy::Lru, 0, vec![400]);
        let g = geom();
        let vc = clock();
        fc.commit(0, 1, &[vec![1u32]], &g, &vc);
        // round 2: the new key 9 precedes the cached-fresh key 1 in the
        // client's key order, and inserting it must evict *something*
        let keys = vec![vec![9u32, 1]];
        let plan = fc.plan_for(0, 2, &keys, &g, &vc);
        assert!(plan.fresh_keys.contains(&(0, 1)));
        let st = fc.commit(0, 2, &keys, &g, &vc);
        assert_eq!(st.lookups, 3);
        assert_eq!(
            st.hits,
            (plan.fresh_keys.len() + plan.fresh_segs.len()) as u64,
            "commit must agree with the plan the session ledgered"
        );
        assert_eq!(st.hits, 2);
        assert_eq!(st.evictions, 1, "key 9 still had to make room");
    }

    #[test]
    fn an_entry_bigger_than_the_budget_is_not_cached() {
        let mut fc = FleetCaches::new(EvictPolicy::Lfu, 0, vec![100]);
        let g = geom();
        let vc = clock();
        let s = fc.commit(0, 1, &[vec![1u32]], &g, &vc);
        assert_eq!(s.evictions, 0);
        assert_eq!(fc.cache(0).unwrap().len(), 0, "200 B pieces cannot fit a 100 B budget");
    }

    #[test]
    fn namespaces_partition_the_address_space_not_the_budget() {
        // two jobs share one device cache: same (keyspace, key) addresses,
        // different namespaces — both coexist, bytes pool in one budget
        let mut fc = FleetCaches::new(EvictPolicy::Lru, 0, vec![10_000]);
        let g = geom();
        let vc_a = clock(); // ns 0
        let mut vc_b = clock().with_ns(1);
        let keys = vec![vec![1u32, 2]];
        fc.commit(0, 1, &keys, &g, &vc_a);
        fc.commit(0, 1, &keys, &g, &vc_b);
        assert!(fc.cache(0).unwrap().contains_ns(0, (0, 1)));
        assert!(fc.cache(0).unwrap().contains_ns(1, (0, 1)));
        assert_eq!(fc.cache(0).unwrap().len(), 6, "both jobs' entries coexist");
        assert_eq!(fc.cache(0).unwrap().used_bytes(), 2 * 600, "one pooled budget");
        // job B's close invalidates only job B's copies
        let spec = ModelArch::logreg(8).select_spec();
        let mut touched = TouchedKeys::new(1);
        touched.record(&[vec![1]]);
        vc_b.bump(1, &touched, &spec);
        let pa = fc.plan_for(0, 2, &keys, &g, &vc_a);
        let pb = fc.plan_for(0, 2, &keys, &g, &vc_b);
        assert!(pa.fresh_keys.contains(&(0, 1)), "job A unaffected");
        assert!(!pb.fresh_keys.contains(&(0, 1)), "job B's row written");
    }

    #[test]
    fn scale_budgets_partitions_the_device_budget() {
        let mut fc = FleetCaches::new(EvictPolicy::Lru, 0, vec![1000, 600]);
        assert_eq!(fc.budgets(), vec![1000, 600]);
        fc.scale_budgets(0.5);
        assert_eq!(fc.budgets(), vec![500, 300]);
        // the full share is exact: scaling by 1.0 changes nothing
        let mut whole = FleetCaches::new(EvictPolicy::Lru, 0, vec![1000, 600]);
        whole.scale_budgets(1.0);
        assert_eq!(whole.budgets(), vec![1000, 600]);
    }

    #[test]
    fn caches_materialize_only_for_committing_clients() {
        let mut fc = FleetCaches::new(EvictPolicy::Lru, 0, vec![10_000; 64]);
        assert_eq!(fc.clients_cached(), 0);
        assert_eq!(fc.resident_bytes(), 0);
        let g = geom();
        let vc = clock();
        fc.commit(3, 1, &[vec![1u32]], &g, &vc);
        assert_eq!(fc.clients_cached(), 1);
        assert!(fc.has_cache(3) && !fc.has_cache(0));
        assert!(fc.resident_bytes() > 0);
        assert!(fc.cache(5).is_none());
        // planning for an untouched client is the all-miss (empty) plan
        assert!(fc.plan_for(5, 1, &[vec![1u32]], &g, &vc).is_empty());
    }

    #[test]
    fn derived_budgets_resolve_at_ensure_time() {
        let mut fc = FleetCaches::derived(EvictPolicy::Lru, 0, 4000, 0.5);
        fc.ensure(2, 600);
        assert_eq!(fc.cache(2).unwrap().budget(), 600);
        assert!(fc.budgets().is_empty(), "derived budgets have no table");
        fc.scale_budgets(0.5);
        assert_eq!(fc.cache(2).unwrap().budget(), 300);
        match fc.budget_source() {
            BudgetSource::Derived { frac, .. } => assert!((*frac - 0.25).abs() < 1e-12),
            BudgetSource::Table(_) => panic!("derived source expected"),
        }
    }

    #[test]
    fn version_distance_evicts_the_most_lagging_entry() {
        let mut fc = FleetCaches::new(EvictPolicy::VersionDistance, 0, vec![600]);
        let g = geom();
        let mut vc = clock();
        fc.commit(0, 1, &[vec![1u32, 2]], &g, &vc);
        // key 2 lags once the server writes it
        let spec = ModelArch::logreg(8).select_spec();
        let mut touched = TouchedKeys::new(1);
        touched.record(&[vec![2]]);
        vc.bump(1, &touched, &spec);
        // key 3 arrives; the victim must be the lagging key 2, not key 1
        fc.commit(0, 2, &[vec![3u32]], &g, &vc);
        assert!(fc.cache(0).unwrap().contains((0, 1)));
        assert!(!fc.cache(0).unwrap().contains((0, 2)));
    }
}

//! Server-side piece versioning: the write clock the delta-fetch protocol
//! compares against.
//!
//! A version is the 1-based round ordinal of the last aggregator write to
//! that row set (0 = the initial model). The trainer bumps the clock after
//! every close that merged at least one update, using the
//! [`TouchedKeys`](crate::aggregation::TouchedKeys) of the merge set —
//! *only* keys an update actually selected bump, so a row nobody wrote
//! keeps its version and every client's cached copy of it stays fresh.
//! Segment-level versions move coarser: a `Binding::Full` segment is
//! written by every merged update (its deltas cover the whole segment), a
//! keyed segment is written whenever any key of its keyspace was touched.

use crate::aggregation::TouchedKeys;
use crate::model::{Binding, ParamStore, SelectSpec};

use super::BROADCAST_SPACE;

/// Whether key `k`'s row set in `update` holds any nonzero value — the
/// same spans `piece_for_key` concatenates, scanned in place (no per-key
/// allocation or copy) with an early return on the first nonzero.
fn row_written(update: &ParamStore, spec: &SelectSpec, ks: usize, key: u32) -> bool {
    for b in &spec.bindings {
        if let Binding::Keyed {
            seg,
            keyspace,
            map,
        } = b
        {
            if *keyspace != ks {
                continue;
            }
            let src = &update.segments[*seg].data;
            let rl = map.row_len;
            for g in 0..map.groups {
                let s = (g * map.keys_total + key as usize) * rl;
                if src[s..s + rl].iter().any(|&v| v != 0.0) {
                    return true;
                }
            }
        }
    }
    false
}

/// Per-(keyspace, key) and per-segment last-write round counters.
#[derive(Clone, Debug)]
pub struct VersionClock {
    /// `keyed[ks][key]` = round of the last aggregator write (0 = initial).
    keyed: Vec<Vec<u64>>,
    /// `segs[seg]` = round of the last write anywhere in the segment.
    segs: Vec<u64>,
    /// Tenancy namespace this clock's pieces live in (0 = single-tenant).
    /// Client caches key their entries by `(ns, piece)` so two jobs' pieces
    /// at the same `(keyspace, key)` address never validate against each
    /// other's versions.
    ns: u32,
}

impl VersionClock {
    /// A fresh clock (everything at the initial version 0) for a model with
    /// the given keyspace sizes and segment count.
    pub fn new(keyspace_sizes: &[usize], num_segs: usize) -> Self {
        VersionClock {
            keyed: keyspace_sizes.iter().map(|&s| vec![0u64; s]).collect(),
            segs: vec![0u64; num_segs],
            ns: 0,
        }
    }

    /// Tag the clock with a tenancy namespace (job id). The namespace does
    /// not change versioning semantics — it prefixes the keyspace so
    /// on-device cache entries of different jobs never collide.
    pub fn with_ns(mut self, ns: u32) -> Self {
        self.ns = ns;
        self
    }

    pub fn ns(&self) -> u32 {
        self.ns
    }

    /// Version of one cache entry: keyed pieces by `(keyspace, key)`,
    /// segment entries by `(BROADCAST_SPACE, segment-index)`. Out-of-range
    /// ids report version 0 (never written).
    pub fn version_of(&self, space: usize, key: u32) -> u64 {
        if space == BROADCAST_SPACE {
            self.segs.get(key as usize).copied().unwrap_or(0)
        } else {
            self.keyed
                .get(space)
                .and_then(|ks| ks.get(key as usize))
                .copied()
                .unwrap_or(0)
        }
    }

    /// Record a close *exactly*: of the keys the merged updates selected,
    /// bump only those whose row in the finalized server `update` is
    /// nonzero somewhere. A zero-aggregate row (e.g. a padded select key no
    /// merged client's data exercises, or a row whose contributions cancel)
    /// provably leaves the store unchanged under the cache-validated server
    /// optimizers (zero update = fixed point), so its cached copies stay
    /// valid — this is what makes re-selecting stable keys actually pay.
    /// Full segments bump only when their update segment is nonzero; keyed
    /// segments when any of their keyspace's rows were written. Returns the
    /// number of keyed rows bumped.
    pub fn bump_written(
        &mut self,
        round: u64,
        selected: &TouchedKeys,
        update: &ParamStore,
        spec: &SelectSpec,
    ) -> usize {
        let mut written = TouchedKeys::new(self.keyed.len());
        for (ks, keys) in selected.keyspaces().enumerate() {
            for &k in keys {
                if row_written(update, spec, ks, k) {
                    written.record_one(ks, k);
                }
            }
        }
        let n = written.count();
        for (ks, keys) in written.keyspaces().enumerate() {
            for &k in keys {
                if let Some(v) = self.keyed.get_mut(ks).and_then(|kv| kv.get_mut(k as usize)) {
                    *v = round;
                }
            }
        }
        for b in &spec.bindings {
            match b {
                Binding::Full { seg } => {
                    if update.segments[*seg].data.iter().any(|&v| v != 0.0) {
                        self.segs[*seg] = round;
                    }
                }
                Binding::Keyed { seg, keyspace, .. } => {
                    if written.count_in(*keyspace) > 0 {
                        self.segs[*seg] = round;
                    }
                }
            }
        }
        n
    }

    /// Conservative form of [`Self::bump_written`]: treat every selected
    /// key as written (no update to inspect). Never serves stale data —
    /// it can only over-invalidate. Used by tests and by callers without
    /// the finalized update at hand.
    pub fn bump(&mut self, round: u64, touched: &TouchedKeys, spec: &SelectSpec) {
        for (ks, keys) in touched.keyspaces().enumerate() {
            for &k in keys {
                if let Some(v) = self.keyed.get_mut(ks).and_then(|kv| kv.get_mut(k as usize)) {
                    *v = round;
                }
            }
        }
        for b in &spec.bindings {
            match b {
                // every merged update's deltas cover the whole segment
                Binding::Full { seg } => self.segs[*seg] = round,
                Binding::Keyed { seg, keyspace, .. } => {
                    if touched.count_in(*keyspace) > 0 {
                        self.segs[*seg] = round;
                    }
                }
            }
        }
    }

    /// Total keyed rows currently past version 0 (test/inspection helper).
    pub fn touched_rows(&self) -> usize {
        self.keyed
            .iter()
            .map(|ks| ks.iter().filter(|&&v| v > 0).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;

    #[test]
    fn bump_moves_only_touched_keys_and_their_segments() {
        let arch = ModelArch::logreg(16);
        let spec = arch.select_spec();
        let mut clock = VersionClock::new(&[16], 2);
        assert_eq!(clock.version_of(0, 5), 0);
        assert_eq!(clock.version_of(BROADCAST_SPACE, 1), 0);

        let mut touched = TouchedKeys::new(1);
        touched.record(&[vec![3, 5]]);
        clock.bump(1, &touched, &spec);
        assert_eq!(clock.version_of(0, 3), 1);
        assert_eq!(clock.version_of(0, 5), 1);
        assert_eq!(clock.version_of(0, 4), 0, "untouched key keeps its version");
        // logreg: segment 0 is the keyed weight matrix, segment 1 the Full bias
        assert_eq!(clock.version_of(BROADCAST_SPACE, 0), 1);
        assert_eq!(clock.version_of(BROADCAST_SPACE, 1), 1);
        assert_eq!(clock.touched_rows(), 2);

        // a later round re-bumps touched keys and leaves the rest alone
        let mut t2 = TouchedKeys::new(1);
        t2.record(&[vec![5]]);
        clock.bump(2, &t2, &spec);
        assert_eq!(clock.version_of(0, 5), 2);
        assert_eq!(clock.version_of(0, 3), 1);
    }

    #[test]
    fn bump_written_skips_zero_aggregate_rows() {
        use crate::tensor::rng::Rng;
        let arch = ModelArch::logreg(16);
        let spec = arch.select_spec();
        let mut update = arch.init_store(&mut Rng::new(1, 0)).zeros_like();
        // the aggregate wrote row 3 of the keyed weight matrix only; row 5
        // was selected but every contribution was zero; the bias segment
        // stays all-zero too
        update.segments[0].data[3 * 50] = 1.0;
        let mut clock = VersionClock::new(&[16], 2);
        let mut selected = TouchedKeys::new(1);
        selected.record(&[vec![3, 5]]);
        let n = clock.bump_written(1, &selected, &update, &spec);
        assert_eq!(n, 1);
        assert_eq!(clock.version_of(0, 3), 1);
        assert_eq!(clock.version_of(0, 5), 0, "zero-aggregate row is not written");
        assert_eq!(clock.version_of(BROADCAST_SPACE, 0), 1, "keyed segment written");
        assert_eq!(
            clock.version_of(BROADCAST_SPACE, 1),
            0,
            "all-zero Full segment keeps its version"
        );
        // an unselected row is never even inspected
        assert_eq!(clock.version_of(0, 7), 0);
    }

    #[test]
    fn out_of_range_lookups_are_version_zero() {
        let clock = VersionClock::new(&[4], 1);
        assert_eq!(clock.version_of(0, 99), 0);
        assert_eq!(clock.version_of(7, 0), 0);
        assert_eq!(clock.version_of(BROADCAST_SPACE, 9), 0);
    }
}

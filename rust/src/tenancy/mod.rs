//! Multi-tenant coordinator: N concurrent training jobs over one shared
//! device fleet, CDN, and client cache budget.
//!
//! The single-tenant [`Trainer`] owns everything — fleet, caches, version
//! clock, slice service. This module promotes it into a long-lived
//! [`Coordinator`] that *ticks*: each tick the [`FleetArbiter`] decides
//! which jobs plan a round (and, under `priority`/`drr`, which clients
//! earlier jobs already claimed), every granted job runs one round of its
//! own Algorithm 2, and the coordinator prices what the tick cost on the
//! shared fleet.
//!
//! **Isolation.** Each job keeps its own model, dataset, optimizer, RNG
//! stream and round engine; shared *addressable* state is namespaced by
//! job id ([`Trainer::set_namespace`]): the CDN prefixes piece addresses,
//! the version clock tags its keyspaces, and client-cache entries carry
//! the namespace — so job A's pieces can never validate against job B's
//! versions. Namespace 0 is byte-identical to an untagged single-tenant
//! run, which is what the byte-identity contract tests pin.
//!
//! **Cache budget.** One physical device hosts every job's cache bytes.
//! [`CacheShare::Partitioned`] gives each caching job a guaranteed
//! weight-share slice of the device budget (a lone job's share is exactly
//! the single-tenant budget); [`CacheShare::Contended`] keeps *one*
//! pooled cache per device — budgeted at the per-job maximum — and swaps
//! it into each job's scheduler around its round, so jobs may evict each
//! other's (namespaced) entries.
//!
//! **The tick clock.** Per-job simulated time stays the job's own ledger
//! (a job's [`TrainReport`] is what its isolated run would report). The
//! coordinator's fleet clock charges each tick
//! `max(slowest job close, busiest shared device) + ROUND_OVERHEAD_S`:
//! jobs' rounds overlap (that is the whole point of sharing the fleet),
//! but a device selected by several jobs trains them sequentially, so the
//! busiest device's summed busy time also bounds the tick. Running N jobs
//! concurrently therefore beats running them back-to-back whenever any
//! two rounds overlap — with identical per-job trajectories under
//! `fair-share`.

pub mod arbiter;
pub mod registry;

pub use arbiter::{ArbiterPolicy, FleetArbiter};
pub use registry::{JobRegistry, JobSpec};

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cache::{CacheShare, FleetCaches};
use crate::coordinator::{EvalRecord, RoundRecord, TrainReport, Trainer};
use crate::error::{Error, Result};
use crate::obs::{HealthRollup, NullRecorder, Recorder, TraceEvent};
use crate::scheduler::ROUND_OVERHEAD_S;

/// One tenant's live state inside the coordinator.
struct JobState {
    spec: JobSpec,
    trainer: Trainer,
    rounds: Vec<RoundRecord>,
    evals: Vec<EvalRecord>,
    /// Rounds completed so far (the job is done at `spec.cfg.rounds`).
    done: usize,
    /// Simulated device-seconds consumed, per fleet tier.
    tier_busy_s: Vec<f64>,
}

/// Per-job fleet usage rollup (see [`crate::metrics::multitenant_summary`]).
#[derive(Clone, Debug)]
pub struct JobUsage {
    pub id: u32,
    pub name: String,
    /// Rounds the job ran (== its grant count).
    pub rounds: usize,
    /// Simulated device-seconds, per fleet tier.
    pub tier_busy_s: Vec<f64>,
    pub down_bytes: u64,
    pub up_bytes: u64,
    pub cache_hits: u64,
    pub cache_lookups: u64,
}

/// What a multi-tenant run produced: one [`TrainReport`] per job (index-
/// aligned with the registry order) plus the shared-fleet rollup.
#[derive(Clone, Debug)]
pub struct MultiReport {
    pub reports: Vec<TrainReport>,
    pub usage: Vec<JobUsage>,
    /// Arbiter ticks the run took.
    pub ticks: u64,
    /// Grants per job, in job order.
    pub grants: Vec<u64>,
    /// Total simulated wall-time on the shared fleet (the coordinator's
    /// tick clock — NOT the sum of per-job `total_sim_s`).
    pub total_sim_s: f64,
    /// Busy device-seconds / (fleet size × `total_sim_s`).
    pub fleet_utilization: f64,
    /// Tier names of the shared fleet, for reporting.
    pub tier_names: Vec<String>,
}

impl MultiReport {
    /// Fleet-wide health rollup across every job's incident ledger. A
    /// method (not a stored field) so it is always consistent with the
    /// per-job reports.
    pub fn health_rollup(&self) -> HealthRollup {
        HealthRollup::fold(self.reports.iter().map(|r| &r.health))
    }
}

/// N concurrent jobs over one shared fleet.
pub struct Coordinator {
    jobs: Vec<JobState>,
    arbiter: FleetArbiter,
    share: CacheShare,
    /// The contended-share cache pool, parked here between rounds and
    /// swapped into the running job's scheduler.
    pooled: Option<FleetCaches>,
    fleet_size: usize,
    tier_names: Vec<String>,
    total_sim_s: f64,
    busy_device_s: f64,
    /// Trace sink for arbiter-level events; job trainers hold their own
    /// clone and tag events with their namespace (see [`set_recorder`]).
    ///
    /// [`set_recorder`]: Coordinator::set_recorder
    recorder: Arc<dyn Recorder>,
}

impl Coordinator {
    pub fn new(registry: JobRegistry, policy: ArbiterPolicy) -> Result<Self> {
        let share = registry.share();
        let mut trainers = Vec::with_capacity(registry.len());
        for spec in registry.jobs() {
            let mut trainer = Trainer::new(spec.cfg.clone())?;
            trainer.set_namespace(spec.id);
            trainers.push(trainer);
        }
        // fleet coherence beyond the registry's config checks: the jobs'
        // datasets must agree on the train-client count, or "client 7" is
        // a different device per job
        let fleet_size = trainers[0].dataset().train.len();
        for (t, spec) in trainers.iter().zip(registry.jobs()) {
            let n = t.dataset().train.len();
            if n != fleet_size {
                return Err(Error::Config(format!(
                    "job {:?} has {} train clients but the shared fleet has {} \
                     (every job's dataset must cover the same device population)",
                    spec.name, n, fleet_size
                )));
            }
        }
        let tier_names: Vec<String> = {
            let fleet = trainers[0].scheduler().fleet();
            (0..fleet.num_tiers()).map(|t| fleet.tier_name(t).to_string()).collect()
        };
        let arbiter = FleetArbiter::new(policy, fleet_size, registry.jobs());

        let mut jobs: Vec<JobState> = registry
            .into_jobs()
            .into_iter()
            .zip(trainers)
            .map(|(spec, trainer)| JobState {
                spec,
                trainer,
                rounds: Vec::new(),
                evals: Vec::new(),
                done: 0,
                tier_busy_s: vec![0.0; tier_names.len()],
            })
            .collect();

        // cache-budget sharing across the fleet's physical devices
        let mut pooled = None;
        match share {
            CacheShare::Partitioned => {
                // each caching job gets its weight share of the device
                // budget; a lone caching job's share is exactly 1.0 and
                // scale_budgets(1.0) is exact, preserving byte-identity
                let total_w: f64 = jobs
                    .iter()
                    .filter(|j| j.trainer.versions().is_some())
                    .map(|j| j.spec.weight)
                    .sum();
                for job in &mut jobs {
                    if job.trainer.versions().is_some() {
                        let frac = job.spec.weight / total_w;
                        if let Some(caches) = job.trainer.scheduler_mut().caches_mut() {
                            caches.scale_budgets(frac);
                        }
                    }
                }
            }
            CacheShare::Contended => {
                // one pooled cache per device, budgeted at the per-job
                // maximum; the registry guaranteed one eviction policy.
                // Budgets resolve through the scheduler (each job's caches
                // derive theirs lazily from the device profiles), so they
                // must be read before the caches are detached.
                let mut budgets = vec![0u64; fleet_size];
                let mut policy_stale = None;
                for job in &mut jobs {
                    if job.trainer.scheduler().caches().is_some() {
                        for (ci, b) in budgets.iter_mut().enumerate() {
                            let own =
                                job.trainer.scheduler().cache_budget_of(ci).unwrap_or(0);
                            *b = (*b).max(own);
                        }
                        let caches = job
                            .trainer
                            .scheduler_mut()
                            .take_caches()
                            .expect("caches checked present");
                        policy_stale = Some((caches.policy(), caches.max_stale_rounds()));
                    }
                }
                if let Some((evict, max_stale)) = policy_stale {
                    pooled = Some(FleetCaches::new(evict, max_stale, budgets));
                }
            }
        }

        Ok(Coordinator {
            jobs,
            arbiter,
            share,
            pooled,
            fleet_size,
            tier_names,
            total_sim_s: 0.0,
            busy_device_s: 0.0,
            recorder: Arc::new(NullRecorder),
        })
    }

    /// Install one trace sink for the whole coordinator: arbiter ticks are
    /// recorded here, and every job's trainer gets a clone so its round
    /// events land in the same trace (distinguished by the `ns` tag each
    /// trainer stamps from its job id).
    pub fn set_recorder(&mut self, recorder: Arc<dyn Recorder>) {
        for job in &mut self.jobs {
            job.trainer.set_recorder(recorder.clone());
        }
        self.recorder = recorder;
    }

    pub fn num_jobs(&self) -> usize {
        self.jobs.len()
    }

    pub fn arbiter(&self) -> &FleetArbiter {
        &self.arbiter
    }

    /// Total simulated wall-time charged to the shared fleet so far.
    pub fn total_sim_s(&self) -> f64 {
        self.total_sim_s
    }

    fn any_active(&self) -> bool {
        self.jobs.iter().any(|j| j.done < j.spec.cfg.rounds)
    }

    /// Run one arbiter tick: every granted job runs one round; the shared
    /// clock advances by what the tick cost the fleet.
    pub fn tick(&mut self) -> Result<()> {
        let active: Vec<bool> = self.jobs.iter().map(|j| j.done < j.spec.cfg.rounds).collect();
        let demands: Vec<usize> = self
            .jobs
            .iter()
            .map(|j| j.trainer.round_engine().planned_cohort(j.spec.cfg.cohort))
            .collect();
        let granted = self.arbiter.tick(&demands, &active);
        if granted.is_empty() {
            return Err(Error::Config(format!(
                "arbiter ({}) granted no job a cohort this tick — a job's \
                 planned cohort exceeds the fleet of {} clients",
                self.arbiter.policy(),
                self.fleet_size
            )));
        }
        if self.recorder.enabled() {
            self.recorder.record(&TraceEvent::Tick {
                tick: self.arbiter.ticks(),
                granted: granted.iter().map(|&ji| self.jobs[ji].spec.id).collect(),
            });
        }
        // fair-share allows overlapping grants (each job's planner sees
        // exactly its isolated-run exclusion set — the byte-identity path);
        // priority/drr exclude clients earlier jobs claimed this tick
        let exclusive = !matches!(self.arbiter.policy(), ArbiterPolicy::FairShare);
        let mut claimed: Vec<usize> = Vec::new();
        let mut close_max = 0.0f64;
        let mut device_busy: BTreeMap<usize, f64> = BTreeMap::new();
        for &ji in &granted {
            let job = &mut self.jobs[ji];
            // contended share: this job trains against the pooled caches
            let swap = self.pooled.is_some() && job.trainer.versions().is_some();
            if swap {
                let pool = self.pooled.take().expect("pooled caches");
                job.trainer.scheduler_mut().install_caches(pool);
            }
            let exclude: &[usize] = if exclusive { &claimed } else { &[] };
            let res = job.trainer.run_round_with(exclude);
            if swap {
                self.pooled = job.trainer.scheduler_mut().take_caches();
            }
            let (rec, tick) = res?;
            close_max = close_max.max(tick.close_s);
            for &(client, at_s) in &tick.busy {
                *device_busy.entry(client).or_insert(0.0) += at_s;
                let tier = job.trainer.scheduler().fleet().profile(client).tier;
                job.tier_busy_s[tier] += at_s;
            }
            if exclusive {
                claimed.extend_from_slice(&tick.cohort);
            }
            job.rounds.push(rec);
            if job.trainer.should_eval(job.done) {
                let eval = job.trainer.evaluate()?;
                job.evals.push(eval);
            }
            job.done += 1;
        }
        let busiest = device_busy.values().fold(0.0f64, |a, &b| a.max(b));
        self.busy_device_s += device_busy.values().sum::<f64>();
        self.total_sim_s += close_max.max(busiest) + ROUND_OVERHEAD_S;
        Ok(())
    }

    /// Tick until every job has run its configured rounds, then assemble
    /// per-job reports (via the same [`Trainer::finish_report`] tail the
    /// single-tenant run loop uses) and the fleet rollup.
    pub fn run(&mut self) -> Result<MultiReport> {
        while self.any_active() {
            self.tick()?;
        }
        let mut reports = Vec::with_capacity(self.jobs.len());
        let mut usage = Vec::with_capacity(self.jobs.len());
        for job in &mut self.jobs {
            let rounds = std::mem::take(&mut job.rounds);
            let evals = std::mem::take(&mut job.evals);
            let report = job.trainer.finish_report(rounds, evals)?;
            usage.push(JobUsage {
                id: job.spec.id,
                name: job.spec.name.clone(),
                rounds: report.rounds.len(),
                tier_busy_s: job.tier_busy_s.clone(),
                down_bytes: report.total_down_bytes,
                up_bytes: report.total_up_bytes,
                cache_hits: report
                    .rounds
                    .iter()
                    .map(|r| r.tier_cache_hits.iter().sum::<u64>())
                    .sum(),
                cache_lookups: report
                    .rounds
                    .iter()
                    .map(|r| r.tier_cache_lookups.iter().sum::<u64>())
                    .sum(),
            });
            reports.push(report);
        }
        let denom = self.fleet_size as f64 * self.total_sim_s;
        Ok(MultiReport {
            reports,
            usage,
            ticks: self.arbiter.ticks(),
            grants: self.arbiter.grants().to_vec(),
            total_sim_s: self.total_sim_s,
            fleet_utilization: if denom > 0.0 {
                (self.busy_device_s / denom).min(1.0)
            } else {
                0.0
            },
            tier_names: self.tier_names.clone(),
        })
    }
}

/// The `share` mode this coordinator was built with.
impl Coordinator {
    pub fn share(&self) -> CacheShare {
        self.share
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, TrainConfig};
    use crate::data::bow::BowConfig;

    fn job_cfg(vocab: usize, rounds: usize) -> TrainConfig {
        let mut cfg = TrainConfig::logreg_default(vocab, 16);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(vocab, 50).with_clients(24, 4, 8));
        cfg.rounds = rounds;
        cfg.cohort = 5;
        cfg.eval.every = 0;
        cfg.eval.max_examples = 128;
        cfg
    }

    #[test]
    fn two_jobs_tick_to_completion() {
        let jobs = vec![
            JobSpec::new(1, "a", job_cfg(128, 3)),
            JobSpec::new(2, "b", job_cfg(256, 2)),
        ];
        let reg = JobRegistry::new(jobs, CacheShare::Partitioned).unwrap();
        let mut coord = Coordinator::new(reg, ArbiterPolicy::FairShare).unwrap();
        let report = coord.run().unwrap();
        assert_eq!(report.reports.len(), 2);
        assert_eq!(report.reports[0].rounds.len(), 3);
        assert_eq!(report.reports[1].rounds.len(), 2);
        // fair-share: both jobs run while both are active, then job a alone
        assert_eq!(report.ticks, 3);
        assert_eq!(report.grants, vec![3, 2]);
        assert!(report.total_sim_s > 0.0);
        assert!(report.fleet_utilization > 0.0 && report.fleet_utilization <= 1.0);
        // the shared clock beats running the jobs back to back
        let sequential: f64 = report.reports.iter().map(|r| r.total_sim_s).sum();
        assert!(report.total_sim_s < sequential);
    }

    #[test]
    fn priority_jobs_claim_disjoint_cohorts() {
        let jobs = vec![
            JobSpec::new(1, "lo", job_cfg(128, 2)).with_priority(1),
            JobSpec::new(2, "hi", job_cfg(128, 2)).with_priority(9),
        ];
        let reg = JobRegistry::new(jobs, CacheShare::Partitioned).unwrap();
        let mut coord = Coordinator::new(reg, ArbiterPolicy::Priority).unwrap();
        coord.tick().unwrap();
        let lo = &coord.jobs[0].rounds[0];
        let hi = &coord.jobs[1].rounds[0];
        // both ran (5 + 5 <= 24 fits), with full cohorts
        assert_eq!(lo.completed + lo.dropped, 5);
        assert_eq!(hi.completed + hi.dropped, 5);
    }

    #[test]
    fn oversized_job_stalls_with_a_clear_error() {
        let mut cfg = job_cfg(128, 1);
        cfg.cohort = 25; // > 24 train clients
        // config validation itself may allow it; the arbiter must not spin
        let jobs = vec![JobSpec::new(1, "big", cfg)];
        if let Ok(reg) = JobRegistry::new(jobs, CacheShare::Partitioned) {
            match Coordinator::new(reg, ArbiterPolicy::DeficitRoundRobin) {
                Ok(mut coord) => {
                    let err = coord.tick().unwrap_err();
                    assert!(err.to_string().contains("granted no job"), "{err}");
                }
                Err(_) => {} // rejected even earlier — also fine
            }
        }
    }
}

//! Fleet arbiter: which job gets which eligible clients, each tick.
//!
//! The arbiter is deliberately RNG-free — its decisions are a pure
//! function of the job specs and the grant history, so a multi-tenant run
//! is deterministic from the run seed (the only randomness lives in each
//! job's own cohort draw). It does not pick clients itself; it decides the
//! *order* jobs plan in and (under `priority`/`drr`) gates admission on
//! fleet capacity, and the coordinator turns earlier grants into the
//! `extra_exclude` set of later jobs' [`Trainer::run_round_with`]
//! (crate::coordinator::Trainer::run_round_with) calls.
//!
//! * [`ArbiterPolicy::FairShare`] — every active job plans every tick,
//!   with *no* cross-job exclusion: jobs may select overlapping clients
//!   (a device trains both models sequentially; the coordinator's tick
//!   clock prices that contention). Because each job's planner sees
//!   exactly the exclusion set it would see running alone, per-job
//!   trajectories are byte-identical to isolated runs.
//! * [`ArbiterPolicy::Priority`] — jobs plan in (priority desc, index asc)
//!   order; each job's grant excludes every client an earlier job claimed
//!   this tick, and jobs stop being admitted once the fleet's capacity is
//!   spoken for. Starvation of low-priority jobs is the policy's nature.
//! * [`ArbiterPolicy::DeficitRoundRobin`] — each active job accrues
//!   `weight` credits per tick; jobs plan in (credit desc, index asc)
//!   order under the same capacity gate, and a granted job pays the
//!   active weight sum. Jobs a full fleet squeezed out accumulate credit
//!   and win later ticks; on a saturated fleet long-run grant rates are
//!   weight-proportional.

use super::registry::JobSpec;

/// How the shared fleet is divided between jobs each tick.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ArbiterPolicy {
    /// All active jobs every tick, overlapping grants allowed.
    #[default]
    FairShare,
    /// Highest priority claims clients first; leftovers trickle down.
    Priority,
    /// Weighted deficit round-robin under the fleet capacity.
    DeficitRoundRobin,
}

impl ArbiterPolicy {
    pub const ALL: [ArbiterPolicy; 3] = [
        ArbiterPolicy::FairShare,
        ArbiterPolicy::Priority,
        ArbiterPolicy::DeficitRoundRobin,
    ];
}

/// Canonical CLI names; `Display` round-trips with `FromStr`.
impl std::fmt::Display for ArbiterPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ArbiterPolicy::FairShare => "fair-share",
            ArbiterPolicy::Priority => "priority",
            ArbiterPolicy::DeficitRoundRobin => "drr",
        })
    }
}

impl std::str::FromStr for ArbiterPolicy {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fair-share" | "fair_share" | "fair" => Ok(ArbiterPolicy::FairShare),
            "priority" => Ok(ArbiterPolicy::Priority),
            "drr" | "deficit-round-robin" | "deficit_round_robin" => {
                Ok(ArbiterPolicy::DeficitRoundRobin)
            }
            other => Err(format!(
                "unknown arbiter policy {other:?} (want {}, {} or {})",
                ArbiterPolicy::FairShare,
                ArbiterPolicy::Priority,
                ArbiterPolicy::DeficitRoundRobin
            )),
        }
    }
}

/// Per-tick job admission over a fleet of `capacity` devices.
#[derive(Clone, Debug)]
pub struct FleetArbiter {
    policy: ArbiterPolicy,
    capacity: usize,
    weights: Vec<f64>,
    priorities: Vec<u32>,
    /// DRR deficit counters, in job-index order.
    credits: Vec<f64>,
    /// Total grants per job across the run.
    grants: Vec<u64>,
    ticks: u64,
}

impl FleetArbiter {
    pub fn new(policy: ArbiterPolicy, capacity: usize, jobs: &[JobSpec]) -> Self {
        FleetArbiter {
            policy,
            capacity,
            weights: jobs.iter().map(|j| j.weight).collect(),
            priorities: jobs.iter().map(|j| j.priority).collect(),
            credits: vec![0.0; jobs.len()],
            grants: vec![0; jobs.len()],
            ticks: 0,
        }
    }

    pub fn policy(&self) -> ArbiterPolicy {
        self.policy
    }

    /// Total grants per job so far, in job-index order.
    pub fn grants(&self) -> &[u64] {
        &self.grants
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Decide which jobs plan this tick, in planning order. `demands[j]` is
    /// job j's planned cohort size (over-selection included) and
    /// `active[j]` whether it still has rounds to run. Deterministic: same
    /// history + same inputs → same grants.
    pub fn tick(&mut self, demands: &[usize], active: &[bool]) -> Vec<usize> {
        assert_eq!(demands.len(), self.weights.len(), "demand arity");
        assert_eq!(active.len(), self.weights.len(), "active arity");
        self.ticks += 1;
        let granted = match self.policy {
            ArbiterPolicy::FairShare => (0..self.weights.len()).filter(|&j| active[j]).collect(),
            ArbiterPolicy::Priority => {
                let mut order: Vec<usize> =
                    (0..self.weights.len()).filter(|&j| active[j]).collect();
                order.sort_by(|&a, &b| {
                    self.priorities[b].cmp(&self.priorities[a]).then(a.cmp(&b))
                });
                self.admit(&order, demands)
            }
            ArbiterPolicy::DeficitRoundRobin => {
                // accrue weight per tick; a grant pays back the *active
                // weight sum*, so on a one-job-per-tick fleet the balance
                // condition `grants_j × Σw ≈ ticks × w_j` makes long-run
                // grant rates weight-proportional (paying a flat 1.0 would
                // let every credit climb at the same rate and the index
                // tie-break starve the lighter jobs)
                let total_w: f64 = (0..self.weights.len())
                    .filter(|&j| active[j])
                    .map(|j| self.weights[j])
                    .sum();
                for j in 0..self.weights.len() {
                    if active[j] {
                        self.credits[j] += self.weights[j];
                    }
                }
                let mut order: Vec<usize> =
                    (0..self.weights.len()).filter(|&j| active[j]).collect();
                order.sort_by(|&a, &b| {
                    self.credits[b].total_cmp(&self.credits[a]).then(a.cmp(&b))
                });
                let granted = self.admit(&order, demands);
                for &j in &granted {
                    self.credits[j] -= total_w;
                }
                granted
            }
        };
        for &j in &granted {
            self.grants[j] += 1;
        }
        granted
    }

    /// Admit jobs in `order` while their cohorts fit the remaining fleet
    /// capacity; a job too big for what's left is skipped, smaller jobs
    /// behind it may still fit.
    fn admit(&self, order: &[usize], demands: &[usize]) -> Vec<usize> {
        let mut used = 0usize;
        let mut granted = Vec::with_capacity(order.len());
        for &j in order {
            if used + demands[j] <= self.capacity {
                used += demands[j];
                granted.push(j);
            }
        }
        granted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn jobs(n: usize) -> Vec<JobSpec> {
        (0..n)
            .map(|i| JobSpec::new(i as u32, format!("j{i}"), TrainConfig::logreg_default(64, 8)))
            .collect()
    }

    #[test]
    fn fair_share_grants_every_active_job_in_order() {
        let js = jobs(3);
        let mut arb = FleetArbiter::new(ArbiterPolicy::FairShare, 10, &js);
        assert_eq!(arb.tick(&[4, 4, 4], &[true, true, true]), vec![0, 1, 2]);
        assert_eq!(arb.tick(&[4, 4, 4], &[true, false, true]), vec![0, 2]);
        assert_eq!(arb.grants(), &[2, 1, 2]);
    }

    #[test]
    fn priority_orders_and_gates_on_capacity() {
        let mut js = jobs(3);
        js[0].priority = 1;
        js[1].priority = 5;
        js[2].priority = 5;
        let mut arb = FleetArbiter::new(ArbiterPolicy::Priority, 10, &js);
        // ties break toward the lower index; job 0 no longer fits
        assert_eq!(arb.tick(&[4, 4, 4], &[true, true, true]), vec![1, 2]);
        // a smaller low-priority job slips into the leftover capacity
        assert_eq!(arb.tick(&[2, 4, 4], &[true, true, true]), vec![1, 2, 0]);
    }

    #[test]
    fn drr_round_robins_under_a_tight_fleet() {
        let js = jobs(3);
        let mut arb = FleetArbiter::new(ArbiterPolicy::DeficitRoundRobin, 10, &js);
        let demands = [6, 6, 6]; // only one job fits per tick
        let active = [true, true, true];
        let mut seq = Vec::new();
        for _ in 0..9 {
            let g = arb.tick(&demands, &active);
            assert_eq!(g.len(), 1);
            seq.push(g[0]);
        }
        // equal weights: perfect rotation, grants within 0 of each other
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
        assert_eq!(arb.grants(), &[3, 3, 3]);
    }

    #[test]
    fn drr_weighted_grants_track_weights() {
        let mut js = jobs(2);
        js[0].weight = 2.0;
        js[1].weight = 1.0;
        let mut arb = FleetArbiter::new(ArbiterPolicy::DeficitRoundRobin, 6, &js);
        let demands = [6, 6];
        let active = [true, true];
        for _ in 0..30 {
            arb.tick(&demands, &active);
        }
        let g = arb.grants();
        // 2:1 weights under a one-job-per-tick fleet → grant ratio within
        // one grant of 2:1
        assert!((g[0] as f64 - 2.0 * g[1] as f64).abs() <= 1.0 + 1e-9, "{g:?}");
        assert_eq!(g[0] + g[1], 30);
    }

    #[test]
    fn arbiter_is_deterministic() {
        let mut js = jobs(4);
        for (i, j) in js.iter_mut().enumerate() {
            j.weight = 1.0 + i as f64 * 0.5;
            j.priority = (i % 2) as u32;
        }
        for policy in ArbiterPolicy::ALL {
            let mut a = FleetArbiter::new(policy, 9, &js);
            let mut b = FleetArbiter::new(policy, 9, &js);
            for t in 0..20 {
                let demands = [3 + t % 3, 4, 2, 5];
                let active = [true, t % 5 != 0, true, true];
                assert_eq!(a.tick(&demands, &active), b.tick(&demands, &active), "{policy}");
            }
            assert_eq!(a.grants(), b.grants());
        }
    }

    #[test]
    fn policy_display_round_trips() {
        for p in ArbiterPolicy::ALL {
            assert_eq!(p.to_string().parse::<ArbiterPolicy>().unwrap(), p);
        }
        assert_eq!(
            "deficit-round-robin".parse::<ArbiterPolicy>().unwrap(),
            ArbiterPolicy::DeficitRoundRobin
        );
        assert!("bogus".parse::<ArbiterPolicy>().is_err());
    }
}

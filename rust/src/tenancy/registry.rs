//! Job registry: the validated, data-driven description of N concurrent
//! training jobs sharing one device fleet.
//!
//! A [`JobSpec`] is one tenant: its own [`TrainConfig`] (model/arch, key
//! policies, aggregation mode, privacy mode, selection policy, rounds and
//! eval cadence) plus the two scheduling knobs the
//! [`FleetArbiter`](crate::tenancy::FleetArbiter) reads — `priority` and
//! `weight`. [`JobRegistry::new`] applies every per-job rule of
//! [`TrainConfig::validate`] and then the cross-job coherence rules: the
//! jobs must describe the *same physical fleet* (equal seed, fleet kind,
//! and memory-cap parameterization — the device profiles are generated
//! deterministically from those), ids and names must be unique (the id is
//! the tenancy namespace prefixing CDN piece addresses, version clocks,
//! and client-cache entries), and a contended cache share needs one
//! agreed-upon eviction policy for the pooled per-device caches.

use crate::cache::CacheShare;
use crate::config::TrainConfig;
use crate::error::{Error, Result};

/// One tenant job. The `id` doubles as the tenancy namespace — keep it
/// unique; namespace 0 hashes identically to a single-tenant run, so the
/// byte-identity tests pin the lone job's id to 0.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: u32,
    pub name: String,
    pub cfg: TrainConfig,
    /// Deficit-round-robin credit rate, and the job's share of a
    /// partitioned cache budget. Must be finite and positive.
    pub weight: f64,
    /// `priority` arbiter rank — higher claims clients first; ties break
    /// toward the lower job index.
    pub priority: u32,
}

impl JobSpec {
    pub fn new(id: u32, name: impl Into<String>, cfg: TrainConfig) -> Self {
        JobSpec {
            id,
            name: name.into(),
            cfg,
            weight: 1.0,
            priority: 0,
        }
    }

    pub fn with_weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    pub fn with_priority(mut self, priority: u32) -> Self {
        self.priority = priority;
        self
    }

    /// Attach per-job SLO rules — each job's trainer runs its own health
    /// monitor over its own round stream, so incident ledgers stay
    /// per-tenant.
    pub fn with_slos(mut self, slos: Vec<crate::obs::SloRule>) -> Self {
        self.cfg.obs.health.slos = slos;
        self
    }
}

/// A validated set of jobs plus the fleet-wide cache-share mode.
#[derive(Clone, Debug)]
pub struct JobRegistry {
    jobs: Vec<JobSpec>,
    share: CacheShare,
}

impl JobRegistry {
    pub fn new(jobs: Vec<JobSpec>, share: CacheShare) -> Result<Self> {
        if jobs.is_empty() {
            return Err(Error::Config("job registry needs at least one job".into()));
        }
        for job in &jobs {
            if job.name.is_empty() {
                return Err(Error::Config(format!("job {} has an empty name", job.id)));
            }
            if !(job.weight.is_finite() && job.weight > 0.0) {
                return Err(Error::Config(format!(
                    "job {:?}: weight must be finite and positive, got {}",
                    job.name, job.weight
                )));
            }
            job.cfg.validate().map_err(|e| {
                Error::Config(format!("job {:?}: invalid config: {e}", job.name))
            })?;
            // the arbiter's grant/exclusion sets and the contended budget
            // table are sized to the shared dataset population; a decoupled
            // fleet or scenario-shaped eligibility would silently escape
            // both (oversized ids are never excluded, pooled budgets read 0)
            if job.cfg.fleet_size > 0 {
                return Err(Error::Config(format!(
                    "job {:?}: --fleet-size is single-tenant only (the arbiter \
                     sizes grants to the shared dataset population)",
                    job.name
                )));
            }
            if job.cfg.scenario.shapes_eligibility() {
                return Err(Error::Config(format!(
                    "job {:?}: churn/outage/wave scenarios are single-tenant \
                     only (arbiter grants do not see scenario eligibility)",
                    job.name
                )));
            }
        }
        for (i, a) in jobs.iter().enumerate() {
            for b in jobs.iter().skip(i + 1) {
                if a.id == b.id {
                    return Err(Error::Config(format!(
                        "jobs {:?} and {:?} share id {} (the id is the tenancy \
                         namespace; it must be unique)",
                        a.name, b.name, a.id
                    )));
                }
                if a.name == b.name {
                    return Err(Error::Config(format!("duplicate job name {:?}", a.name)));
                }
            }
        }
        // fleet coherence: device profiles are generated deterministically
        // from (kind, seed, mem_cap_frac) — every job must see the same
        // physical devices or "client 7" means different hardware per job
        let first = &jobs[0];
        for job in &jobs[1..] {
            if job.cfg.seed != first.cfg.seed {
                return Err(Error::Config(format!(
                    "jobs {:?} and {:?} disagree on the run seed ({} vs {}); \
                     the shared fleet is generated from it",
                    first.name, job.name, first.cfg.seed, job.cfg.seed
                )));
            }
            if job.cfg.fleet != first.cfg.fleet {
                return Err(Error::Config(format!(
                    "jobs {:?} and {:?} disagree on the fleet kind ({} vs {})",
                    first.name, job.name, first.cfg.fleet, job.cfg.fleet
                )));
            }
            if job.cfg.mem_cap_frac != first.cfg.mem_cap_frac {
                return Err(Error::Config(format!(
                    "jobs {:?} and {:?} disagree on mem_cap_frac ({} vs {}); \
                     it parameterizes the shared device profiles",
                    first.name, job.name, first.cfg.mem_cap_frac, job.cfg.mem_cap_frac
                )));
            }
        }
        if share == CacheShare::Contended {
            // one pooled cache per device: a single eviction policy and
            // staleness bound must govern it
            let cache_jobs: Vec<&JobSpec> = jobs.iter().filter(|j| j.cfg.cache).collect();
            if let Some(first) = cache_jobs.first() {
                for job in &cache_jobs[1..] {
                    if job.cfg.cache_evict != first.cfg.cache_evict {
                        return Err(Error::Config(format!(
                            "contended cache share: jobs {:?} and {:?} disagree on \
                             the eviction policy ({} vs {})",
                            first.name, job.name, first.cfg.cache_evict, job.cfg.cache_evict
                        )));
                    }
                    if job.cfg.max_stale_rounds != first.cfg.max_stale_rounds {
                        return Err(Error::Config(format!(
                            "contended cache share: jobs {:?} and {:?} disagree on \
                             max_stale_rounds ({} vs {})",
                            first.name, job.name, first.cfg.max_stale_rounds,
                            job.cfg.max_stale_rounds
                        )));
                    }
                }
            }
        }
        Ok(JobRegistry { jobs, share })
    }

    pub fn jobs(&self) -> &[JobSpec] {
        &self.jobs
    }

    pub fn share(&self) -> CacheShare {
        self.share
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    pub fn into_jobs(self) -> Vec<JobSpec> {
        self.jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetConfig;
    use crate::data::bow::BowConfig;

    fn cfg(vocab: usize) -> TrainConfig {
        let mut cfg = TrainConfig::logreg_default(vocab, 16);
        cfg.dataset = DatasetConfig::Bow(BowConfig::new(vocab, 50).with_clients(24, 4, 8));
        cfg.rounds = 2;
        cfg.cohort = 4;
        cfg
    }

    #[test]
    fn heterogeneous_jobs_with_one_fleet_validate() {
        let jobs = vec![
            JobSpec::new(1, "a", cfg(128)),
            JobSpec::new(2, "b", cfg(256)).with_weight(2.0).with_priority(3),
        ];
        let reg = JobRegistry::new(jobs, CacheShare::Partitioned).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.jobs()[1].priority, 3);
    }

    #[test]
    fn duplicate_ids_and_names_are_rejected() {
        let dup_id = vec![JobSpec::new(1, "a", cfg(128)), JobSpec::new(1, "b", cfg(128))];
        assert!(JobRegistry::new(dup_id, CacheShare::Partitioned).is_err());
        let dup_name = vec![JobSpec::new(1, "a", cfg(128)), JobSpec::new(2, "a", cfg(128))];
        assert!(JobRegistry::new(dup_name, CacheShare::Partitioned).is_err());
    }

    #[test]
    fn fleet_incoherence_is_rejected() {
        let mut other = cfg(128);
        other.seed = 99;
        let jobs = vec![JobSpec::new(1, "a", cfg(128)), JobSpec::new(2, "b", other)];
        let err = JobRegistry::new(jobs, CacheShare::Partitioned).unwrap_err();
        assert!(err.to_string().contains("seed"), "{err}");

        let mut other = cfg(128);
        other.fleet = crate::scheduler::FleetKind::Tiered3;
        let jobs = vec![JobSpec::new(1, "a", cfg(128)), JobSpec::new(2, "b", other)];
        assert!(JobRegistry::new(jobs, CacheShare::Partitioned).is_err());
    }

    #[test]
    fn contended_share_requires_one_eviction_policy() {
        let mut a = cfg(128);
        a.cache = true;
        let mut b = cfg(256);
        b.cache = true;
        b.cache_evict = crate::cache::EvictPolicy::Lfu;
        let jobs = vec![JobSpec::new(1, "a", a.clone()), JobSpec::new(2, "b", b.clone())];
        assert!(JobRegistry::new(jobs.clone(), CacheShare::Contended).is_err());
        // partitioned shares are isolated — disagreement is fine there
        assert!(JobRegistry::new(jobs, CacheShare::Partitioned).is_ok());
    }

    #[test]
    fn per_job_config_validation_applies() {
        let mut bad = cfg(128);
        bad.cohort = 0;
        let jobs = vec![JobSpec::new(1, "a", bad)];
        let err = JobRegistry::new(jobs, CacheShare::Partitioned).unwrap_err();
        assert!(err.to_string().contains("job \"a\""), "{err}");
    }

    #[test]
    fn fleet_scale_knobs_are_single_tenant_only() {
        let mut oversized = cfg(128);
        oversized.fleet_size = 5000;
        let jobs = vec![JobSpec::new(1, "a", oversized)];
        let err = JobRegistry::new(jobs, CacheShare::Partitioned).unwrap_err();
        assert!(err.to_string().contains("fleet-size"), "{err}");

        let mut churny = cfg(128);
        churny.scenario.churn = Some(crate::fleet::ChurnSpec {
            rate_per_h: 0.1,
            width_frac: 0.9,
        });
        let jobs = vec![JobSpec::new(1, "a", churny)];
        let err = JobRegistry::new(jobs, CacheShare::Partitioned).unwrap_err();
        assert!(err.to_string().contains("single-tenant"), "{err}");
    }

    #[test]
    fn nonpositive_weights_are_rejected() {
        let jobs = vec![JobSpec::new(1, "a", cfg(128)).with_weight(0.0)];
        assert!(JobRegistry::new(jobs, CacheShare::Partitioned).is_err());
    }
}

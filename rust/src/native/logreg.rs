//! Native multi-label logistic regression: one epoch of minibatch SGD and
//! the recall@5 eval, mirroring `model.logreg_client_update` / `logreg_eval`.

use crate::error::{Error, Result};
use crate::tensor::ops::{bce_with_logits, matmul, matmul_at_b, sigmoid, top_k_indices};

use super::Buf;

/// params: [w (m*t), b (t)]; batch: [x (s*mb*m), y (s*mb*t), wgt (s*mb)].
/// Returns deltas [dw, db] with delta = initial - final.
#[allow(clippy::too_many_arguments)]
pub fn logreg_client_update(
    params: &[Vec<f32>],
    batch: &[Buf],
    m: usize,
    t: usize,
    steps: usize,
    mb: usize,
    lr: f32,
) -> Result<Vec<Vec<f32>>> {
    if params.len() != 2 || batch.len() != 3 {
        return Err(Error::Shape("logreg expects 2 params, 3 batch bufs".into()));
    }
    let (w0, b0) = (&params[0], &params[1]);
    if w0.len() != m * t || b0.len() != t {
        return Err(Error::Shape(format!(
            "logreg param sizes w={} b={} vs m*t={} t={}",
            w0.len(),
            b0.len(),
            m * t,
            t
        )));
    }
    let x = batch[0].as_f32()?;
    let y = batch[1].as_f32()?;
    let wgt = batch[2].as_f32()?;
    if x.len() != steps * mb * m || y.len() != steps * mb * t || wgt.len() != steps * mb {
        return Err(Error::Shape("logreg batch sizes mismatch".into()));
    }

    let mut w = w0.clone();
    let mut b = b0.clone();
    let mut logits = vec![0.0f32; mb * t];
    let mut gz = vec![0.0f32; mb * t];
    for s in 0..steps {
        let xs = &x[s * mb * m..(s + 1) * mb * m];
        let ys = &y[s * mb * t..(s + 1) * mb * t];
        let ws = &wgt[s * mb..(s + 1) * mb];
        let wsum: f32 = ws.iter().sum::<f32>().max(1.0);
        // logits = xs @ w + b
        matmul(xs, &w, &mut logits, mb, m, t);
        for i in 0..mb {
            let f = ws[i] / wsum;
            for j in 0..t {
                let z = logits[i * t + j] + b[j];
                gz[i * t + j] = (sigmoid(z) - ys[i * t + j]) * f;
            }
        }
        // w -= lr * xsᵀ @ gz ; b -= lr * Σ_i gz[i]
        matmul_at_b(xs, &gz, &mut w, mb, m, t, -lr);
        for i in 0..mb {
            for j in 0..t {
                b[j] -= lr * gz[i * t + j];
            }
        }
    }
    let dw: Vec<f32> = w0.iter().zip(w.iter()).map(|(a, b)| a - b).collect();
    let db: Vec<f32> = b0.iter().zip(b.iter()).map(|(a, b)| a - b).collect();
    Ok(vec![dw, db])
}

/// params: [w (n*t), b (t)]; batch: [x (bsz*n), y (bsz*t), wgt (bsz)].
/// Returns (loss_sum, recall@5_sum, weight_sum).
pub fn logreg_eval(
    params: &[Vec<f32>],
    batch: &[Buf],
    n: usize,
    t: usize,
) -> Result<(f64, f64, f64)> {
    let (w, b) = (&params[0], &params[1]);
    if w.len() != n * t || b.len() != t {
        return Err(Error::Shape("logreg eval param sizes".into()));
    }
    let x = batch[0].as_f32()?;
    let y = batch[1].as_f32()?;
    let wgt = batch[2].as_f32()?;
    let bsz = wgt.len();
    if x.len() != bsz * n || y.len() != bsz * t {
        return Err(Error::Shape("logreg eval batch sizes".into()));
    }
    let mut logits = vec![0.0f32; bsz * t];
    matmul(x, w, &mut logits, bsz, n, t);
    let mut loss_sum = 0.0f64;
    let mut rec5_sum = 0.0f64;
    let mut wsum = 0.0f64;
    for i in 0..bsz {
        let wi = wgt[i];
        let row = &mut logits[i * t..(i + 1) * t];
        for (j, l) in row.iter_mut().enumerate() {
            *l += b[j];
        }
        let yrow = &y[i * t..(i + 1) * t];
        let loss: f32 = row
            .iter()
            .zip(yrow.iter())
            .map(|(&z, &yy)| bce_with_logits(z, yy))
            .sum();
        let top5 = top_k_indices(row, 5);
        let hits: f32 = top5.iter().map(|&j| yrow[j]).sum();
        let ntags: f32 = yrow.iter().sum::<f32>().max(1.0);
        loss_sum += (loss * wi) as f64;
        rec5_sum += (hits / ntags * wi) as f64;
        wsum += wi as f64;
    }
    Ok((loss_sum, rec5_sum, wsum))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    fn setup(m: usize, t: usize, steps: usize, mb: usize) -> (Vec<Vec<f32>>, Vec<Buf>) {
        let mut rng = Rng::new(8, 0);
        let w = rand_vec(&mut rng, m * t, 0.01);
        let b = vec![0.0; t];
        let x: Vec<f32> = (0..steps * mb * m)
            .map(|_| if rng.f32() < 0.1 { 1.0 } else { 0.0 })
            .collect();
        let y: Vec<f32> = (0..steps * mb * t)
            .map(|_| if rng.f32() < 0.2 { 1.0 } else { 0.0 })
            .collect();
        let wgt = vec![1.0f32; steps * mb];
        (
            vec![w, b],
            vec![Buf::F32(x), Buf::F32(y), Buf::F32(wgt)],
        )
    }

    #[test]
    fn zero_lr_zero_delta() {
        let (p, batch) = setup(16, 4, 2, 4);
        let d = logreg_client_update(&p, &batch, 16, 4, 2, 4, 0.0).unwrap();
        assert!(d[0].iter().all(|&v| v == 0.0));
        assert!(d[1].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn training_reduces_eval_loss() {
        let (p, batch) = setup(16, 4, 4, 8);
        // evaluate on the training batch (flattened to one eval batch)
        let flat_eval = |params: &[Vec<f32>]| {
            let x = batch[0].as_f32().unwrap().to_vec();
            let y = batch[1].as_f32().unwrap().to_vec();
            let wgt = vec![1.0f32; 32];
            let eb = vec![Buf::F32(x), Buf::F32(y), Buf::F32(wgt)];
            logreg_eval(params, &eb, 16, 4).unwrap().0
        };
        let loss0 = flat_eval(&p);
        let d = logreg_client_update(&p, &batch, 16, 4, 4, 8, 0.5).unwrap();
        let p1: Vec<Vec<f32>> = p
            .iter()
            .zip(d.iter())
            .map(|(pp, dd)| pp.iter().zip(dd.iter()).map(|(a, b)| a - b).collect())
            .collect();
        let loss1 = flat_eval(&p1);
        assert!(loss1 < loss0, "{loss1} !< {loss0}");
    }

    #[test]
    fn padded_examples_do_not_matter() {
        let (p, batch) = setup(16, 4, 2, 4);
        let mut wgt = vec![1.0f32; 8];
        wgt[3] = 0.0;
        wgt[7] = 0.0;
        let mk = |x: Vec<f32>| {
            vec![
                Buf::F32(x),
                batch[1].clone(),
                Buf::F32(wgt.clone()),
            ]
        };
        let x0 = batch[0].as_f32().unwrap().to_vec();
        let mut x1 = x0.clone();
        for v in &mut x1[3 * 16..4 * 16] {
            *v = 42.0;
        }
        let d0 = logreg_client_update(&p, &mk(x0), 16, 4, 2, 4, 0.1).unwrap();
        let d1 = logreg_client_update(&p, &mk(x1), 16, 4, 2, 4, 0.1).unwrap();
        for (a, b) in d0[0].iter().zip(d1[0].iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn eval_perfect_model_has_recall_one() {
        let t = 8;
        let n = 4;
        let w = vec![0.0f32; n * t];
        let mut b = vec![-10.0f32; t];
        b[0] = 10.0;
        b[1] = 10.0;
        let x = vec![0.0f32; 2 * n];
        let mut y = vec![0.0f32; 2 * t];
        y[0] = 1.0; // ex0: tag 0
        y[t] = 1.0;
        y[t + 1] = 1.0; // ex1: tags 0,1
        let batch = vec![Buf::F32(x), Buf::F32(y), Buf::F32(vec![1.0, 1.0])];
        let (_, rec, ws) = logreg_eval(&[w, b], &batch, n, t).unwrap();
        assert!((rec / ws - 1.0).abs() < 1e-6);
    }

    #[test]
    fn shape_errors_are_reported() {
        let (p, batch) = setup(16, 4, 2, 4);
        assert!(logreg_client_update(&p, &batch, 17, 4, 2, 4, 0.1).is_err());
        assert!(logreg_client_update(&p[..1], &batch, 16, 4, 2, 4, 0.1).is_err());
    }
}

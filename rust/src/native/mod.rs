//! Pure-Rust compute engine mirroring the L2 JAX math.
//!
//! Two roles:
//!
//! 1. **Test oracle** — `rust/tests/pjrt_parity.rs` asserts the PJRT path
//!    (AOT artifacts) and this implementation agree to float tolerance on
//!    identical inputs, pinning the cross-language numeric contract.
//! 2. **Fast sweep engine** — the logreg/MLP experiment grids can run
//!    without artifacts (`--engine native`), useful for CI and for the
//!    criterion benches that isolate coordinator overhead from XLA.
//!
//! Supports the logreg and 2NN families (training + eval). The CNN and
//! transformer families are PJRT-only by design: their client updates run
//! through the compiled artifacts (conv/attention backward is exactly what
//! we delegate to XLA), and the native engine returns a descriptive error.
//!
//! The math matches `python/compile/model.py` op-for-op: one epoch of
//! minibatch SGD over `[steps, mb, ...]` batches, weighted losses with the
//! `max(Σw, 1)` padding guard, delta = initial − final.

mod logreg;
mod mlp;

pub use logreg::{logreg_client_update, logreg_eval};
pub use mlp::{mlp_client_update, mlp_eval};

use crate::error::{Error, Result};
use crate::model::ModelArch;

/// Raw engine input buffer (matches artifact input dtypes).
#[derive(Clone, Debug)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Buf {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Buf::F32(v) => Ok(v),
            Buf::I32(_) => Err(Error::Shape("expected f32 buffer, got i32".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Buf::I32(v) => Ok(v),
            Buf::F32(_) => Err(Error::Shape("expected i32 buffer, got f32".into())),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.len() * 4
    }
}

/// Native client-update dispatch (slices in artifact parameter order,
/// batch in artifact batch order).
pub fn client_update(
    arch: &ModelArch,
    ms: &[usize],
    params: &[Vec<f32>],
    batch: &[Buf],
    lr: f32,
) -> Result<Vec<Vec<f32>>> {
    match arch {
        ModelArch::Logreg { tags, .. } => {
            let b = arch.cu_batch();
            logreg_client_update(params, batch, ms[0], *tags, b.steps, b.mb, lr)
        }
        ModelArch::Mlp {
            hidden, classes, ..
        } => {
            let b = arch.cu_batch();
            mlp_client_update(params, batch, ms[0], *hidden, *classes, b.steps, b.mb, lr)
        }
        other => Err(Error::Artifact(format!(
            "native engine does not implement {other:?} client updates; \
             build artifacts and use the PJRT engine"
        ))),
    }
}

/// Native eval dispatch over one padded eval batch.
/// Returns (loss_sum, metric_sum, weight_sum).
pub fn eval(
    arch: &ModelArch,
    params: &[Vec<f32>],
    batch: &[Buf],
) -> Result<(f64, f64, f64)> {
    match arch {
        ModelArch::Logreg { vocab, tags } => logreg_eval(params, batch, *vocab, *tags),
        ModelArch::Mlp {
            neurons,
            hidden,
            classes,
        } => mlp_eval(params, batch, *neurons, *hidden, *classes),
        other => Err(Error::Artifact(format!(
            "native engine does not implement {other:?} eval; \
             build artifacts and use the PJRT engine"
        ))),
    }
}

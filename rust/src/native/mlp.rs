//! Native 2NN MLP: one epoch of minibatch SGD and eval, mirroring
//! `model.mlp2nn_client_update` / `mlp2nn_eval` (784 -> m -> hidden -> C,
//! ReLU activations, weighted softmax cross-entropy).

use crate::error::{Error, Result};
use crate::tensor::ops::{log_softmax_rows, matmul, matmul_at_b, matmul_b_t, relu_inplace};

use super::Buf;

const IN_DIM: usize = 784;

struct Dims {
    m: usize,
    h: usize,
    c: usize,
}

fn check_params(params: &[Vec<f32>], d: &Dims) -> Result<()> {
    let want = [
        IN_DIM * d.m,
        d.m,
        d.m * d.h,
        d.h,
        d.h * d.c,
        d.c,
    ];
    if params.len() != 6 {
        return Err(Error::Shape(format!("mlp expects 6 params, got {}", params.len())));
    }
    for (i, (p, &w)) in params.iter().zip(want.iter()).enumerate() {
        if p.len() != w {
            return Err(Error::Shape(format!(
                "mlp param {i} has len {}, want {w}",
                p.len()
            )));
        }
    }
    Ok(())
}

/// Forward pass for a minibatch; returns (h1, mask1, h2, mask2, logits).
fn forward(
    params: &[Vec<f32>],
    x: &[f32],
    bsz: usize,
    d: &Dims,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (w1, b1, w2, b2, w3, b3) = (
        &params[0], &params[1], &params[2], &params[3], &params[4], &params[5],
    );
    let mut h1 = vec![0.0f32; bsz * d.m];
    matmul(x, w1, &mut h1, bsz, IN_DIM, d.m);
    for i in 0..bsz {
        for j in 0..d.m {
            h1[i * d.m + j] += b1[j];
        }
    }
    let mask1 = relu_inplace(&mut h1);
    let mut h2 = vec![0.0f32; bsz * d.h];
    matmul(&h1, w2, &mut h2, bsz, d.m, d.h);
    for i in 0..bsz {
        for j in 0..d.h {
            h2[i * d.h + j] += b2[j];
        }
    }
    let mask2 = relu_inplace(&mut h2);
    let mut logits = vec![0.0f32; bsz * d.c];
    matmul(&h2, w3, &mut logits, bsz, d.h, d.c);
    for i in 0..bsz {
        for j in 0..d.c {
            logits[i * d.c + j] += b3[j];
        }
    }
    (h1, mask1, h2, mask2, logits)
}

/// params: [w1, b1, w2, b2, w3, b3]; batch: [x (s*mb*784) f32,
/// y (s*mb) i32, wgt (s*mb) f32]. Returns 6 deltas (initial - final).
#[allow(clippy::too_many_arguments)]
pub fn mlp_client_update(
    params: &[Vec<f32>],
    batch: &[Buf],
    m: usize,
    h: usize,
    c: usize,
    steps: usize,
    mb: usize,
    lr: f32,
) -> Result<Vec<Vec<f32>>> {
    let d = Dims { m, h, c };
    check_params(params, &d)?;
    if batch.len() != 3 {
        return Err(Error::Shape("mlp expects 3 batch bufs".into()));
    }
    let x = batch[0].as_f32()?;
    let y = batch[1].as_i32()?;
    let wgt = batch[2].as_f32()?;
    if x.len() != steps * mb * IN_DIM || y.len() != steps * mb || wgt.len() != steps * mb {
        return Err(Error::Shape("mlp batch sizes mismatch".into()));
    }

    let p0 = params.to_vec();
    let mut p: Vec<Vec<f32>> = params.to_vec();
    for s in 0..steps {
        let xs = &x[s * mb * IN_DIM..(s + 1) * mb * IN_DIM];
        let ys = &y[s * mb..(s + 1) * mb];
        let ws = &wgt[s * mb..(s + 1) * mb];
        let wsum: f32 = ws.iter().sum::<f32>().max(1.0);

        let (h1, mask1, h2, mask2, mut logits) = forward(&p, xs, mb, &d);
        // dlogits = (softmax - onehot) * w / wsum
        log_softmax_rows(&mut logits, mb, c);
        let mut dlogits = logits;
        for i in 0..mb {
            let f = ws[i] / wsum;
            for j in 0..c {
                let sm = dlogits[i * c + j].exp();
                let oh = if ys[i] as usize == j { 1.0 } else { 0.0 };
                dlogits[i * c + j] = (sm - oh) * f;
            }
        }
        // grads layer 3
        let mut dh2 = vec![0.0f32; mb * d.h];
        matmul_b_t(&dlogits, &p[4], &mut dh2, mb, c, d.h);
        for (v, msk) in dh2.iter_mut().zip(mask2.iter()) {
            *v *= msk;
        }
        // grads layer 2
        let mut dh1 = vec![0.0f32; mb * d.m];
        matmul_b_t(&dh2, &p[2], &mut dh1, mb, d.h, d.m);
        for (v, msk) in dh1.iter_mut().zip(mask1.iter()) {
            *v *= msk;
        }
        // SGD updates (weights via xᵀ·g accumulation with -lr)
        matmul_at_b(&h2, &dlogits, &mut p[4], mb, d.h, c, -lr);
        for i in 0..mb {
            for j in 0..c {
                p[5][j] -= lr * dlogits[i * c + j];
            }
        }
        matmul_at_b(&h1, &dh2, &mut p[2], mb, d.m, d.h, -lr);
        for i in 0..mb {
            for j in 0..d.h {
                p[3][j] -= lr * dh2[i * d.h + j];
            }
        }
        matmul_at_b(xs, &dh1, &mut p[0], mb, IN_DIM, d.m, -lr);
        for i in 0..mb {
            for j in 0..d.m {
                p[1][j] -= lr * dh1[i * d.m + j];
            }
        }
    }
    Ok(p0
        .iter()
        .zip(p.iter())
        .map(|(a, b)| a.iter().zip(b.iter()).map(|(x0, x1)| x0 - x1).collect())
        .collect())
}

/// Full-model eval. Returns (loss_sum, weighted_correct, weight_sum).
pub fn mlp_eval(
    params: &[Vec<f32>],
    batch: &[Buf],
    m: usize,
    h: usize,
    c: usize,
) -> Result<(f64, f64, f64)> {
    let d = Dims { m, h, c };
    check_params(params, &d)?;
    let x = batch[0].as_f32()?;
    let y = batch[1].as_i32()?;
    let wgt = batch[2].as_f32()?;
    let bsz = wgt.len();
    if x.len() != bsz * IN_DIM || y.len() != bsz {
        return Err(Error::Shape("mlp eval batch sizes".into()));
    }
    let (_, _, _, _, mut logits) = forward(&params.to_vec(), x, bsz, &d);
    log_softmax_rows(&mut logits, bsz, c);
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut wsum = 0.0f64;
    for i in 0..bsz {
        let wi = wgt[i] as f64;
        let row = &logits[i * c..(i + 1) * c];
        let yi = y[i] as usize;
        loss += -row[yi] as f64 * wi;
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == yi {
            correct += wi;
        }
        wsum += wi;
    }
    Ok((loss, correct, wsum))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    fn setup(m: usize, steps: usize, mb: usize, c: usize) -> (Vec<Vec<f32>>, Vec<Buf>) {
        let mut rng = Rng::new(12, 0);
        let arch = ModelArch::Mlp {
            neurons: m,
            hidden: 32,
            classes: c,
        };
        let store = arch.init_store(&mut rng);
        let params: Vec<Vec<f32>> = store.segments.into_iter().map(|s| s.data).collect();
        let x: Vec<f32> = (0..steps * mb * IN_DIM).map(|_| rng.normal() * 0.5).collect();
        let y: Vec<i32> = (0..steps * mb).map(|_| rng.below(c) as i32).collect();
        let wgt = vec![1.0f32; steps * mb];
        (params, vec![Buf::F32(x), Buf::I32(y), Buf::F32(wgt)])
    }

    #[test]
    fn zero_lr_zero_delta() {
        let (p, b) = setup(16, 2, 4, 5);
        let d = mlp_client_update(&p, &b, 16, 32, 5, 2, 4, 0.0).unwrap();
        assert!(d.iter().all(|t| t.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn training_reduces_loss() {
        let (p, b) = setup(32, 4, 8, 4);
        let eval_batch = vec![
            Buf::F32(b[0].as_f32().unwrap().to_vec()),
            Buf::I32(b[1].as_i32().unwrap().to_vec()),
            Buf::F32(vec![1.0; 32]),
        ];
        let (l0, _, w0) = mlp_eval(&p, &eval_batch, 32, 32, 4).unwrap();
        let d = mlp_client_update(&p, &b, 32, 32, 4, 4, 8, 0.1).unwrap();
        let p1: Vec<Vec<f32>> = p
            .iter()
            .zip(d.iter())
            .map(|(pp, dd)| pp.iter().zip(dd.iter()).map(|(a, x)| a - x).collect())
            .collect();
        let (l1, _, _) = mlp_eval(&p1, &eval_batch, 32, 32, 4).unwrap();
        assert!(l1 / w0 < l0 / w0, "loss {l1} !< {l0}");
    }

    #[test]
    fn eval_counts_are_bounded() {
        let (p, _) = setup(16, 1, 1, 5);
        let mut rng = Rng::new(3, 0);
        let bsz = 10;
        let x: Vec<f32> = (0..bsz * IN_DIM).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..bsz).map(|_| rng.below(5) as i32).collect();
        let batch = vec![Buf::F32(x), Buf::I32(y), Buf::F32(vec![1.0; bsz])];
        let (loss, correct, wsum) = mlp_eval(&p, &batch, 16, 32, 5).unwrap();
        assert!(loss > 0.0);
        assert!(correct >= 0.0 && correct <= wsum);
        assert_eq!(wsum, 10.0);
    }

    #[test]
    fn gradient_check_single_step_full_batch() {
        // numeric gradient of the loss wrt one w3 entry ≈ delta / lr
        let (p, b) = setup(8, 1, 4, 3);
        let lr = 1e-3f32;
        let d = mlp_client_update(&p, &b, 8, 32, 3, 1, 4, lr).unwrap();
        // loss fn on the same single minibatch
        let loss_of = |params: &[Vec<f32>]| -> f64 {
            let eb = vec![
                Buf::F32(b[0].as_f32().unwrap().to_vec()),
                Buf::I32(b[1].as_i32().unwrap().to_vec()),
                Buf::F32(vec![1.0; 4]),
            ];
            let (l, _, w) = mlp_eval(params, &eb, 8, 32, 3).unwrap();
            l / w
        };
        let eps = 1e-3f32;
        for &idx in &[0usize, 17, 40] {
            let mut pp = p.clone();
            pp[4][idx] += eps;
            let lp = loss_of(&pp);
            pp[4][idx] -= 2.0 * eps;
            let lm = loss_of(&pp);
            let num_grad = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let ana_grad = d[4][idx] / lr;
            assert!(
                (num_grad - ana_grad).abs() < 2e-2 * (1.0 + num_grad.abs()),
                "idx {idx}: numeric {num_grad} vs analytic {ana_grad}"
            );
        }
    }
}

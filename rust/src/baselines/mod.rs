//! Baseline configurations the paper compares against.
//!
//! * [`full_broadcast`] — plain FedAvg-style training without FedSelect:
//!   every client takes all keys and the slice service is Option 1
//!   (BROADCAST). By §3.3 this is exactly `m = K`; the paper's "m = n
//!   recovers training without FEDSELECT".
//! * [`federated_dropout`] — Caldas et al. 2018-style baseline: one random
//!   sub-model per round shared by all clients (`FixedPerRound` keys), which
//!   the server could implement with BROADCAST of the sub-model (Fig. 6's
//!   "fixed" arm).

use crate::config::TrainConfig;
use crate::fedselect::{KeyPolicy, SliceImpl};

/// Turn a FedSelect run into its no-selection (full broadcast) baseline.
pub fn full_broadcast(mut cfg: TrainConfig) -> TrainConfig {
    cfg.policies = cfg.policies.iter().map(|_| KeyPolicy::AllKeys).collect();
    cfg.slice_impl = SliceImpl::Broadcast;
    cfg
}

/// Turn per-client random selection into Federated-Dropout-style shared
/// random sub-models (same m, one key set per round for everyone).
pub fn federated_dropout(mut cfg: TrainConfig) -> TrainConfig {
    cfg.policies = cfg
        .policies
        .iter()
        .map(|p| match *p {
            KeyPolicy::RandomGlobal { m }
            | KeyPolicy::RandomLocal { m }
            | KeyPolicy::RandomTopLocal { m }
            | KeyPolicy::TopFreq { m }
            | KeyPolicy::FixedPerRound { m } => KeyPolicy::FixedPerRound { m },
            KeyPolicy::AllKeys => KeyPolicy::AllKeys,
        })
        .collect();
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_broadcast_has_relative_size_one() {
        let cfg = full_broadcast(TrainConfig::logreg_default(128, 16));
        assert_eq!(cfg.policies, vec![KeyPolicy::AllKeys]);
        assert_eq!(cfg.slice_impl, SliceImpl::Broadcast);
        cfg.validate().unwrap();
    }

    #[test]
    fn federated_dropout_shares_keys_per_round() {
        let cfg = federated_dropout(TrainConfig::mlp_default(50));
        assert_eq!(cfg.policies, vec![KeyPolicy::FixedPerRound { m: 50 }]);
        cfg.validate().unwrap();
    }
}

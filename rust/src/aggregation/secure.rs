//! Secure Aggregation simulation (Bonawitz et al. 2017; paper §4.2).
//!
//! Simulates the pairwise-mask protocol over the *deselected* (full model
//! space) client updates — the "apply φ at the client, then dense secure
//! aggregation" strategy §4.2 describes as directly inheriting the system's
//! dense-aggregation privacy, at the cost of full-model-sized uploads.
//!
//! The crypto is replaced by its algebra: client i and j derive a shared
//! pairwise mask vector from a shared seed; i adds it, j subtracts it, so
//! the server-visible sum of masked vectors equals the true sum while no
//! individual vector is ever in the clear. Dropout recovery is simulated by
//! reconstructing (removing) a dropped client's pairwise masks from the
//! survivors' shares, as the real protocol does with Shamir shares.

use crate::error::{Error, Result};
use crate::model::{ParamStore, SelectSpec};
use crate::tensor::rng::Rng;

use super::{finalize_mean, AggMode, Aggregator};

/// One client's masked submission (full model space, flattened per segment).
struct Masked {
    client: u64,
    vecs: Vec<Vec<f32>>,
    counts: Vec<Vec<f32>>,
}

/// Pairwise-mask secure aggregation over deselected updates.
pub struct SecureAggSim {
    template: ParamStore,
    cohort: Vec<u64>,
    round_seed: u64,
    submissions: Vec<Masked>,
    dropped: std::collections::HashSet<u64>,
    /// bytes a client uploads under this scheme (full model!, §4.2).
    pub up_bytes_per_client: u64,
}

impl SecureAggSim {
    /// `cohort` is the set of client ids that agreed on pairwise seeds.
    pub fn new(store: &ParamStore, cohort: Vec<u64>, round_seed: u64) -> Self {
        SecureAggSim {
            template: store.zeros_like(),
            up_bytes_per_client: store.bytes() as u64,
            cohort,
            round_seed,
            submissions: Vec::new(),
            dropped: std::collections::HashSet::new(),
        }
    }

    fn pair_mask(&self, a: u64, b: u64, seg_len: usize, seg_idx: usize) -> Vec<f32> {
        // deterministic mask for the ordered pair (min, max)
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let seed = self
            .round_seed
            .wrapping_mul(0x2545F4914F6CDD1D)
            .wrapping_add(lo.wrapping_mul(0x9E3779B97F4A7C15))
            .wrapping_add(hi.wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(seg_idx as u64);
        let mut rng = Rng::new(seed, 77);
        (0..seg_len).map(|_| rng.normal()).collect()
    }

    /// Client-side: deselect locally, mask, submit.
    pub fn submit(
        &mut self,
        client: u64,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        // φ at the client: expand to full model space
        let mut acc = self.template.clone();
        let mut cnt = self.template.clone();
        spec.deselect_add(&mut acc, &mut cnt, keys, updates)?;
        let mut vecs: Vec<Vec<f32>> = acc.segments.into_iter().map(|s| s.data).collect();
        let counts: Vec<Vec<f32>> = cnt.segments.into_iter().map(|s| s.data).collect();
        // pairwise masks with every other cohort member
        for &other in &self.cohort {
            if other == client {
                continue;
            }
            let sign = if client < other { 1.0f32 } else { -1.0f32 };
            for (si, v) in vecs.iter_mut().enumerate() {
                let mask = self.pair_mask(client, other, v.len(), si);
                for (x, m) in v.iter_mut().zip(mask.iter()) {
                    *x += sign * m;
                }
            }
        }
        self.submissions.push(Masked {
            client,
            vecs,
            counts,
        });
        Ok(())
    }

    /// A cohort member dropped after seed agreement but before submitting:
    /// survivors' masks with it must be reconstructed and removed.
    pub fn mark_dropped(&mut self, client: u64) {
        self.dropped.insert(client);
    }

    /// Server-side: sum masked submissions; pairwise masks cancel, masks
    /// involving dropped clients are reconstructed (simulated) and removed.
    pub fn unmask_sum(&self) -> (ParamStore, ParamStore) {
        let mut acc = self.template.clone();
        let mut counts = self.template.clone();
        for sub in &self.submissions {
            for (seg, v) in acc.segments.iter_mut().zip(sub.vecs.iter()) {
                for (d, &x) in seg.data.iter_mut().zip(v.iter()) {
                    *d += x;
                }
            }
            for (seg, v) in counts.segments.iter_mut().zip(sub.counts.iter()) {
                for (d, &x) in seg.data.iter_mut().zip(v.iter()) {
                    *d += x;
                }
            }
        }
        // remove masks shared with dropped clients (they never submitted the
        // cancelling half)
        for sub in &self.submissions {
            for &dropped in &self.dropped {
                if dropped == sub.client {
                    continue;
                }
                let sign = if sub.client < dropped { 1.0f32 } else { -1.0 };
                for (si, seg) in acc.segments.iter_mut().enumerate() {
                    let mask = self.pair_mask(sub.client, dropped, seg.data.len(), si);
                    for (d, m) in seg.data.iter_mut().zip(mask.iter()) {
                        *d -= sign * m;
                    }
                }
            }
        }
        (acc, counts)
    }
}

impl Aggregator for SecureAggSim {
    fn add_client(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        let id = self
            .cohort
            .get(self.submissions.len())
            .copied()
            .unwrap_or(self.submissions.len() as u64);
        self.submit(id, spec, keys, updates)
    }

    fn add_client_weighted(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
        weight: f32,
    ) -> Result<()> {
        if weight == 1.0 {
            return self.add_client(spec, keys, updates);
        }
        // a client scaling its own masked vector would scale its masks too,
        // so pairwise masks no longer cancel across unequal weights
        Err(Error::Config(
            "secure aggregation cannot apply per-client staleness weights \
             (pairwise masks only cancel at equal scale); use --agg-mode sync"
                .into(),
        ))
    }

    fn finalize(self: Box<Self>, mode: AggMode) -> ParamStore {
        let n = self.submissions.len();
        let (acc, counts) = self.unmask_sum();
        finalize_mean(acc, &counts, n, mode)
    }

    fn num_clients(&self) -> usize {
        self.submissions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;

    fn setup() -> (ParamStore, SelectSpec) {
        let arch = ModelArch::logreg(8);
        let store = arch.init_store(&mut Rng::new(4, 0));
        (store, arch.select_spec())
    }

    #[test]
    fn masks_cancel_and_match_plain_sum() {
        let (store, spec) = setup();
        let cohort = vec![10u64, 20, 30];
        let mut sec = SecureAggSim::new(&store, cohort.clone(), 999);
        let mut plain = super::super::SparseAccumulator::new(&store);
        for (i, &cid) in cohort.iter().enumerate() {
            let keys = vec![vec![i as u32, (i + 3) as u32]];
            let ups = vec![vec![(i + 1) as f32; 2 * 50], vec![0.5; 50]];
            sec.submit(cid, &spec, &keys, &ups).unwrap();
            plain.add_client(&spec, &keys, &ups).unwrap();
        }
        let (sum, counts) = sec.unmask_sum();
        let (psum, pcounts) = plain.raw();
        for (a, b) in sum.segments.iter().zip(psum.segments.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert!((x - y).abs() < 2e-3, "masked sum {x} != plain {y}");
            }
        }
        for (a, b) in counts.segments.iter().zip(pcounts.segments.iter()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn individual_submissions_are_masked() {
        let (store, spec) = setup();
        let mut sec = SecureAggSim::new(&store, vec![1, 2], 7);
        let ups = vec![vec![0.0; 50], vec![0.0; 50]];
        sec.submit(1, &spec, &[vec![0]], &ups).unwrap();
        // an all-zero update must NOT be visible as all-zero on the wire
        let wire = &sec.submissions[0].vecs[0];
        assert!(wire.iter().any(|&x| x.abs() > 1e-3));
    }

    #[test]
    fn dropout_recovery_removes_orphan_masks() {
        let (store, spec) = setup();
        let cohort = vec![1u64, 2, 3];
        let mut sec = SecureAggSim::new(&store, cohort, 42);
        let ups1 = vec![vec![1.0; 50], vec![1.0; 50]];
        let ups2 = vec![vec![2.0; 50], vec![2.0; 50]];
        sec.submit(1, &spec, &[vec![0]], &ups1).unwrap();
        sec.submit(2, &spec, &[vec![0]], &ups2).unwrap();
        // client 3 drops without submitting
        sec.mark_dropped(3);
        let (sum, _) = sec.unmask_sum();
        assert!((sum.segments[0].data[0] - 3.0).abs() < 2e-3);
        assert!((sum.segments[1].data[0] - 3.0).abs() < 2e-3);
    }
}

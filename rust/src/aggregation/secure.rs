//! Secure Aggregation simulation (Bonawitz et al. 2017; paper §4.2).
//!
//! Simulates the pairwise-mask protocol over the *deselected* (full model
//! space) client updates — the "apply φ at the client, then dense secure
//! aggregation" strategy §4.2 describes as directly inheriting the system's
//! dense-aggregation privacy, at the cost of full-model-sized uploads.
//!
//! Two simulations live here:
//!
//! * [`SecureAggSim`] — the original whole-cohort protocol over f32 masks.
//!   The crypto is replaced by its algebra: client i and j derive a shared
//!   pairwise mask vector from a shared seed; i adds it, j subtracts it, so
//!   the server-visible sum of masked vectors equals the true sum while no
//!   individual vector is ever in the clear. Float masks only cancel to
//!   rounding (~1e-3), which is why it is pinned to the synchronous barrier.
//! * [`SecAggCommittee`] — a *close-group committee*: the members that merge
//!   together at one goal-count close (over-select / buffered rounds) are
//!   re-keyed against each other only. Like the real protocol, it operates
//!   over a finite group — here `Z_2^64` fixed-point
//!   ([`fp_quantize`]/[`fp_dequantize`]) with wrapping arithmetic — so
//!   pairwise masks cancel **bit-exactly** and the masked committee sum is
//!   byte-identical to the unmasked sum, including under dropout recovery.
//!   Members that were keyed into a committee but never submit (over-select
//!   stragglers, staleness discards) have their orphan masks reconstructed
//!   and removed per committee, as the real protocol does with Shamir
//!   shares — a straggler poisons only its committee's algebra, never the
//!   global sum. Staleness weights are applied by the server to the
//!   *unmasked committee sum* (every member of a committee shares one close
//!   group, hence one staleness class), which is what preserves the
//!   equal-scale mask algebra that [`SecureAggSim`] cannot offer under
//!   per-client weights.

use crate::error::{Error, Result};
use crate::model::{ParamStore, SelectSpec};
use crate::tensor::rng::Rng;

use super::{finalize_mean, AggMode, Aggregator, TouchedKeys};

/// Fractional bits of the committee fixed-point encoding: updates are
/// quantized to `round(x * 2^20)` in two's complement before masking, the
/// resolution the byte-identity contract is stated at.
pub const COMMITTEE_FP_BITS: u32 = 20;
const FP_SCALE: f64 = (1u64 << COMMITTEE_FP_BITS) as f64;

/// Quantize one f32 to the committee's `Z_2^64` fixed-point encoding.
pub fn fp_quantize(x: f32) -> u64 {
    ((x as f64 * FP_SCALE).round() as i64) as u64
}

/// Invert [`fp_quantize`] (after wrapping sums: interpret as two's
/// complement and rescale).
pub fn fp_dequantize(v: u64) -> f32 {
    ((v as i64) as f64 / FP_SCALE) as f32
}

/// Distinct mask streams for the update vector and the selection-count
/// vector (counts are privacy-sensitive too: they reveal which keys a
/// client selected).
const MASK_STREAM_VEC: u64 = 0x5EC_A66;
const MASK_STREAM_CNT: u64 = 0xC0_47F;

/// Deterministic seed of the pair (a, b)'s mask stream over segment
/// `seg_idx` — order-insensitive in (a, b), shared by both the
/// whole-cohort and the committee protocol so the derivation can only be
/// changed in one place.
fn pair_seed(base: u64, a: u64, b: u64, seg_idx: usize) -> u64 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    base.wrapping_mul(0x2545F4914F6CDD1D)
        .wrapping_add(lo.wrapping_mul(0x9E3779B97F4A7C15))
        .wrapping_add(hi.wrapping_mul(0xBF58476D1CE4E5B9))
        .wrapping_add(seg_idx as u64)
}

/// One committee member's masked fixed-point submission.
struct MaskedQ {
    member: u64,
    vecs: Vec<Vec<u64>>,
    counts: Vec<Vec<u64>>,
}

/// Close-group secure-aggregation committee over `Z_2^64` fixed point.
///
/// `members` is the full keyed set — everyone the server, at close time,
/// asked to re-key and mask against each other. Submitters mask against
/// *every* other member; members that never submit must be
/// [`mark_dropped`](Self::mark_dropped)ed so their orphan masks are
/// reconstructed and removed in [`unmask_sum`](Self::unmask_sum).
pub struct SecAggCommittee {
    template: ParamStore,
    members: Vec<u64>,
    committee_seed: u64,
    submissions: Vec<MaskedQ>,
    dropped: std::collections::HashSet<u64>,
    /// Union of the submitters' select keys — the server learns this
    /// *anyway* from the key lists the fetch protocol already reveals, so
    /// tracking it here costs no privacy and lets the version clock bump
    /// from the close without a trainer-side union.
    touched: TouchedKeys,
    /// Bytes one member uploads: TWO full-model-sized vectors of u64 group
    /// elements — the masked update and the masked selection counts (16
    /// bytes/coordinate total; counts are masked too because they reveal
    /// which keys the client selected).
    pub up_bytes_per_client: u64,
}

impl SecAggCommittee {
    /// `committee_seed` keys every pairwise mask of this committee; the
    /// trainer derives it from `run_seed ^ close_ordinal` (plus the
    /// staleness class), so two closes never share mask material.
    pub fn new(store: &ParamStore, members: Vec<u64>, committee_seed: u64) -> Self {
        SecAggCommittee {
            template: store.zeros_like(),
            up_bytes_per_client: store.num_params() as u64 * 16,
            members,
            committee_seed,
            submissions: Vec::new(),
            dropped: std::collections::HashSet::new(),
            touched: TouchedKeys::default(),
        }
    }

    pub fn members(&self) -> &[u64] {
        &self.members
    }

    pub fn num_submitters(&self) -> usize {
        self.submissions.len()
    }

    /// Union of the submitters' select keys (see the field doc).
    pub fn touched(&self) -> &TouchedKeys {
        &self.touched
    }

    fn pair_mask_q(&self, a: u64, b: u64, len: usize, seg_idx: usize, stream: u64) -> Vec<u64> {
        let mut rng = Rng::new(pair_seed(self.committee_seed, a, b, seg_idx), stream);
        (0..len).map(|_| rng.next_u64()).collect()
    }

    /// Member-side: φ at the client, quantize, mask against every committee
    /// peer, submit. The pair (i, j) shares one mask; i (the smaller id)
    /// adds it and j subtracts it, so the wrapping sum cancels exactly.
    pub fn submit(
        &mut self,
        member: u64,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        if !self.members.contains(&member) {
            return Err(Error::Config(format!(
                "client {member} is not a member of this secure-agg committee"
            )));
        }
        let mut acc = self.template.clone();
        let mut cnt = self.template.clone();
        spec.deselect_add(&mut acc, &mut cnt, keys, updates)?;
        let mut vecs: Vec<Vec<u64>> = acc
            .segments
            .iter()
            .map(|s| s.data.iter().map(|&x| fp_quantize(x)).collect())
            .collect();
        let mut counts: Vec<Vec<u64>> = cnt
            .segments
            .iter()
            .map(|s| s.data.iter().map(|&x| fp_quantize(x)).collect())
            .collect();
        for &other in &self.members {
            if other == member {
                continue;
            }
            let add = member < other;
            for (si, v) in vecs.iter_mut().enumerate() {
                let mask = self.pair_mask_q(member, other, v.len(), si, MASK_STREAM_VEC);
                for (x, m) in v.iter_mut().zip(mask) {
                    *x = if add { x.wrapping_add(m) } else { x.wrapping_sub(m) };
                }
            }
            for (si, v) in counts.iter_mut().enumerate() {
                let mask = self.pair_mask_q(member, other, v.len(), si, MASK_STREAM_CNT);
                for (x, m) in v.iter_mut().zip(mask) {
                    *x = if add { x.wrapping_add(m) } else { x.wrapping_sub(m) };
                }
            }
        }
        self.touched.record(keys);
        self.submissions.push(MaskedQ {
            member,
            vecs,
            counts,
        });
        Ok(())
    }

    /// A keyed member will never submit (over-select straggler past the
    /// close, buffered update past the staleness bound): survivors' masks
    /// with it must be reconstructed and removed.
    pub fn mark_dropped(&mut self, member: u64) {
        self.dropped.insert(member);
    }

    /// Server-side: wrapping-sum the masked submissions (pairwise masks
    /// cancel bit-exactly), reconstruct and remove orphan masks shared with
    /// dropped members, dequantize. Returns `(sum, counts)` in full model
    /// space.
    pub fn unmask_sum(&self) -> (ParamStore, ParamStore) {
        let mut acc_q: Vec<Vec<u64>> = self
            .template
            .segments
            .iter()
            .map(|s| vec![0u64; s.data.len()])
            .collect();
        let mut cnt_q: Vec<Vec<u64>> = acc_q.clone();
        for sub in &self.submissions {
            for (dst, src) in acc_q.iter_mut().zip(sub.vecs.iter()) {
                for (d, &x) in dst.iter_mut().zip(src.iter()) {
                    *d = d.wrapping_add(x);
                }
            }
            for (dst, src) in cnt_q.iter_mut().zip(sub.counts.iter()) {
                for (d, &x) in dst.iter_mut().zip(src.iter()) {
                    *d = d.wrapping_add(x);
                }
            }
        }
        // a member that did submit must not have "its" masks removed: its
        // own submission already carries the cancelling halves
        let submitted: std::collections::HashSet<u64> =
            self.submissions.iter().map(|s| s.member).collect();
        for sub in &self.submissions {
            for &d in &self.dropped {
                if d == sub.member || submitted.contains(&d) {
                    continue;
                }
                let add = sub.member < d;
                for (si, dst) in acc_q.iter_mut().enumerate() {
                    let mask = self.pair_mask_q(sub.member, d, dst.len(), si, MASK_STREAM_VEC);
                    for (x, m) in dst.iter_mut().zip(mask) {
                        // remove exactly what the submitter applied
                        *x = if add { x.wrapping_sub(m) } else { x.wrapping_add(m) };
                    }
                }
                for (si, dst) in cnt_q.iter_mut().enumerate() {
                    let mask = self.pair_mask_q(sub.member, d, dst.len(), si, MASK_STREAM_CNT);
                    for (x, m) in dst.iter_mut().zip(mask) {
                        *x = if add { x.wrapping_sub(m) } else { x.wrapping_add(m) };
                    }
                }
            }
        }
        let mut acc = self.template.clone();
        let mut counts = self.template.clone();
        for (seg, q) in acc.segments.iter_mut().zip(acc_q.iter()) {
            for (d, &v) in seg.data.iter_mut().zip(q.iter()) {
                *d = fp_dequantize(v);
            }
        }
        for (seg, q) in counts.segments.iter_mut().zip(cnt_q.iter()) {
            for (d, &v) in seg.data.iter_mut().zip(q.iter()) {
                *d = fp_dequantize(v);
            }
        }
        (acc, counts)
    }
}

/// One client's masked submission (full model space, flattened per segment).
struct Masked {
    client: u64,
    vecs: Vec<Vec<f32>>,
    counts: Vec<Vec<f32>>,
}

/// Pairwise-mask secure aggregation over deselected updates.
pub struct SecureAggSim {
    template: ParamStore,
    cohort: Vec<u64>,
    round_seed: u64,
    submissions: Vec<Masked>,
    dropped: std::collections::HashSet<u64>,
    /// Union of submitters' select keys (the fetch protocol reveals these
    /// to the server regardless; see [`SecAggCommittee::touched`]).
    touched: TouchedKeys,
    /// bytes a client uploads under this scheme (full model!, §4.2).
    pub up_bytes_per_client: u64,
}

impl SecureAggSim {
    /// `cohort` is the set of client ids that agreed on pairwise seeds.
    pub fn new(store: &ParamStore, cohort: Vec<u64>, round_seed: u64) -> Self {
        SecureAggSim {
            template: store.zeros_like(),
            up_bytes_per_client: store.bytes() as u64,
            cohort,
            round_seed,
            submissions: Vec::new(),
            dropped: std::collections::HashSet::new(),
            touched: TouchedKeys::default(),
        }
    }

    fn pair_mask(&self, a: u64, b: u64, seg_len: usize, seg_idx: usize) -> Vec<f32> {
        let mut rng = Rng::new(pair_seed(self.round_seed, a, b, seg_idx), 77);
        (0..seg_len).map(|_| rng.normal()).collect()
    }

    /// Client-side: deselect locally, mask, submit.
    pub fn submit(
        &mut self,
        client: u64,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        // φ at the client: expand to full model space
        let mut acc = self.template.clone();
        let mut cnt = self.template.clone();
        spec.deselect_add(&mut acc, &mut cnt, keys, updates)?;
        let mut vecs: Vec<Vec<f32>> = acc.segments.into_iter().map(|s| s.data).collect();
        let counts: Vec<Vec<f32>> = cnt.segments.into_iter().map(|s| s.data).collect();
        // pairwise masks with every other cohort member
        for &other in &self.cohort {
            if other == client {
                continue;
            }
            let sign = if client < other { 1.0f32 } else { -1.0f32 };
            for (si, v) in vecs.iter_mut().enumerate() {
                let mask = self.pair_mask(client, other, v.len(), si);
                for (x, m) in v.iter_mut().zip(mask.iter()) {
                    *x += sign * m;
                }
            }
        }
        self.touched.record(keys);
        self.submissions.push(Masked {
            client,
            vecs,
            counts,
        });
        Ok(())
    }

    /// A cohort member dropped after seed agreement but before submitting:
    /// survivors' masks with it must be reconstructed and removed.
    pub fn mark_dropped(&mut self, client: u64) {
        self.dropped.insert(client);
    }

    /// Union of the submitters' select keys (server-visible metadata; the
    /// payloads stay masked).
    pub fn touched(&self) -> &TouchedKeys {
        &self.touched
    }

    /// Server-side: sum masked submissions; pairwise masks cancel, masks
    /// involving dropped clients are reconstructed (simulated) and removed.
    pub fn unmask_sum(&self) -> (ParamStore, ParamStore) {
        let mut acc = self.template.clone();
        let mut counts = self.template.clone();
        for sub in &self.submissions {
            for (seg, v) in acc.segments.iter_mut().zip(sub.vecs.iter()) {
                for (d, &x) in seg.data.iter_mut().zip(v.iter()) {
                    *d += x;
                }
            }
            for (seg, v) in counts.segments.iter_mut().zip(sub.counts.iter()) {
                for (d, &x) in seg.data.iter_mut().zip(v.iter()) {
                    *d += x;
                }
            }
        }
        // remove masks shared with dropped clients (they never submitted the
        // cancelling half)
        for sub in &self.submissions {
            for &dropped in &self.dropped {
                if dropped == sub.client {
                    continue;
                }
                let sign = if sub.client < dropped { 1.0f32 } else { -1.0 };
                for (si, seg) in acc.segments.iter_mut().enumerate() {
                    let mask = self.pair_mask(sub.client, dropped, seg.data.len(), si);
                    for (d, m) in seg.data.iter_mut().zip(mask.iter()) {
                        *d -= sign * m;
                    }
                }
            }
        }
        (acc, counts)
    }
}

impl Aggregator for SecureAggSim {
    fn add_client(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        let id = self
            .cohort
            .get(self.submissions.len())
            .copied()
            .unwrap_or(self.submissions.len() as u64);
        self.submit(id, spec, keys, updates)
    }

    fn add_client_weighted(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
        weight: f32,
    ) -> Result<()> {
        if weight == 1.0 {
            return self.add_client(spec, keys, updates);
        }
        // a client scaling its own masked vector would scale its masks too,
        // so pairwise masks no longer cancel across unequal weights
        Err(Error::Config(
            "secure aggregation cannot apply per-client staleness weights \
             (pairwise masks only cancel at equal scale); use --agg-mode sync"
                .into(),
        ))
    }

    fn finalize(self: Box<Self>, mode: AggMode) -> (ParamStore, TouchedKeys) {
        let n = self.submissions.len();
        let (acc, counts) = self.unmask_sum();
        (finalize_mean(acc, &counts, n, mode), self.touched)
    }

    fn num_clients(&self) -> usize {
        self.submissions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;

    fn setup() -> (ParamStore, SelectSpec) {
        let arch = ModelArch::logreg(8);
        let store = arch.init_store(&mut Rng::new(4, 0));
        (store, arch.select_spec())
    }

    #[test]
    fn masks_cancel_and_match_plain_sum() {
        let (store, spec) = setup();
        let cohort = vec![10u64, 20, 30];
        let mut sec = SecureAggSim::new(&store, cohort.clone(), 999);
        let mut plain = super::super::SparseAccumulator::new(&store);
        for (i, &cid) in cohort.iter().enumerate() {
            let keys = vec![vec![i as u32, (i + 3) as u32]];
            let ups = vec![vec![(i + 1) as f32; 2 * 50], vec![0.5; 50]];
            sec.submit(cid, &spec, &keys, &ups).unwrap();
            plain.add_client(&spec, &keys, &ups).unwrap();
        }
        let (sum, counts) = sec.unmask_sum();
        let (psum, pcounts) = plain.raw();
        for (a, b) in sum.segments.iter().zip(psum.segments.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert!((x - y).abs() < 2e-3, "masked sum {x} != plain {y}");
            }
        }
        for (a, b) in counts.segments.iter().zip(pcounts.segments.iter()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn individual_submissions_are_masked() {
        let (store, spec) = setup();
        let mut sec = SecureAggSim::new(&store, vec![1, 2], 7);
        let ups = vec![vec![0.0; 50], vec![0.0; 50]];
        sec.submit(1, &spec, &[vec![0]], &ups).unwrap();
        // an all-zero update must NOT be visible as all-zero on the wire
        let wire = &sec.submissions[0].vecs[0];
        assert!(wire.iter().any(|&x| x.abs() > 1e-3));
    }

    #[test]
    fn dropout_recovery_removes_orphan_masks() {
        let (store, spec) = setup();
        let cohort = vec![1u64, 2, 3];
        let mut sec = SecureAggSim::new(&store, cohort, 42);
        let ups1 = vec![vec![1.0; 50], vec![1.0; 50]];
        let ups2 = vec![vec![2.0; 50], vec![2.0; 50]];
        sec.submit(1, &spec, &[vec![0]], &ups1).unwrap();
        sec.submit(2, &spec, &[vec![0]], &ups2).unwrap();
        // client 3 drops without submitting
        sec.mark_dropped(3);
        let (sum, _) = sec.unmask_sum();
        assert!((sum.segments[0].data[0] - 3.0).abs() < 2e-3);
        assert!((sum.segments[1].data[0] - 3.0).abs() < 2e-3);
    }

    /// The committee's byte-identity reference: quantize each submitter's
    /// deselected full-space update, wrapping-sum, dequantize — computed
    /// with no masking at all.
    fn quantized_reference(
        store: &ParamStore,
        spec: &SelectSpec,
        clients: &[(Vec<Vec<u32>>, Vec<Vec<f32>>)],
    ) -> (ParamStore, ParamStore) {
        let mut acc_q: Vec<Vec<u64>> = store
            .segments
            .iter()
            .map(|s| vec![0u64; s.data.len()])
            .collect();
        let mut cnt_q = acc_q.clone();
        for (keys, ups) in clients {
            let mut acc = store.zeros_like();
            let mut cnt = store.zeros_like();
            spec.deselect_add(&mut acc, &mut cnt, keys, ups).unwrap();
            for (dst, seg) in acc_q.iter_mut().zip(acc.segments.iter()) {
                for (d, &x) in dst.iter_mut().zip(seg.data.iter()) {
                    *d = d.wrapping_add(fp_quantize(x));
                }
            }
            for (dst, seg) in cnt_q.iter_mut().zip(cnt.segments.iter()) {
                for (d, &x) in dst.iter_mut().zip(seg.data.iter()) {
                    *d = d.wrapping_add(fp_quantize(x));
                }
            }
        }
        let mut acc = store.zeros_like();
        let mut counts = store.zeros_like();
        for (seg, q) in acc.segments.iter_mut().zip(acc_q.iter()) {
            for (d, &v) in seg.data.iter_mut().zip(q.iter()) {
                *d = fp_dequantize(v);
            }
        }
        for (seg, q) in counts.segments.iter_mut().zip(cnt_q.iter()) {
            for (d, &v) in seg.data.iter_mut().zip(q.iter()) {
                *d = fp_dequantize(v);
            }
        }
        (acc, counts)
    }

    fn assert_stores_bit_equal(a: &ParamStore, b: &ParamStore, label: &str) {
        for (sa, sb) in a.segments.iter().zip(b.segments.iter()) {
            for (i, (x, y)) in sa.data.iter().zip(sb.data.iter()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{label}: {} diverges at {i}: {x} vs {y}",
                    sa.name
                );
            }
        }
    }

    #[test]
    fn fp_quantize_round_trips_counts_and_small_updates() {
        for x in [0.0f32, 1.0, -1.0, 3.0, 0.5, -0.25] {
            assert_eq!(fp_dequantize(fp_quantize(x)), x, "{x}");
        }
        // wrapping add/sub of the same mask is the identity, bit for bit
        let v = fp_quantize(0.3);
        let m = 0xDEAD_BEEF_CAFE_F00Du64;
        assert_eq!(v.wrapping_add(m).wrapping_sub(m), v);
    }

    #[test]
    fn committee_masked_sum_is_byte_identical_to_unmasked_sum() {
        let (store, spec) = setup();
        let members = vec![30u64, 10, 20]; // unsorted on purpose
        let mut com = SecAggCommittee::new(&store, members.clone(), 0xC0117EE);
        let mut clients = Vec::new();
        for (i, &cid) in members.iter().enumerate() {
            let keys = vec![vec![i as u32, (i + 4) as u32]];
            let ups = vec![vec![0.125 * (i as f32 + 1.0); 2 * 50], vec![-0.5; 50]];
            com.submit(cid, &spec, &keys, &ups).unwrap();
            clients.push((keys, ups));
        }
        let (sum, counts) = com.unmask_sum();
        let (rsum, rcounts) = quantized_reference(&store, &spec, &clients);
        assert_stores_bit_equal(&sum, &rsum, "sum");
        assert_stores_bit_equal(&counts, &rcounts, "counts");
    }

    #[test]
    fn committee_dropout_recovery_is_byte_exact() {
        let (store, spec) = setup();
        // five keyed members; two never submit (an over-select straggler
        // pair past the close) — recovery must remove exactly their masks
        let members = vec![7u64, 3, 11, 5, 9];
        let mut com = SecAggCommittee::new(&store, members, 20260730);
        let mut clients = Vec::new();
        for (i, cid) in [7u64, 11, 9].into_iter().enumerate() {
            let keys = vec![vec![(2 * i) as u32]];
            let ups = vec![vec![1.0 + i as f32; 50], vec![0.75; 50]];
            com.submit(cid, &spec, &keys, &ups).unwrap();
            clients.push((keys, ups));
        }
        com.mark_dropped(3);
        com.mark_dropped(5);
        let (sum, counts) = com.unmask_sum();
        let (rsum, rcounts) = quantized_reference(&store, &spec, &clients);
        assert_stores_bit_equal(&sum, &rsum, "sum under dropout");
        assert_stores_bit_equal(&counts, &rcounts, "counts under dropout");
    }

    #[test]
    fn committee_submissions_are_masked_on_the_wire() {
        let (store, spec) = setup();
        let mut com = SecAggCommittee::new(&store, vec![1, 2], 99);
        let ups = vec![vec![0.0; 50], vec![0.0; 50]];
        com.submit(1, &spec, &[vec![0]], &ups).unwrap();
        // an all-zero update must not be all-zero (or all-tiny) on the wire;
        // counts are masked too — they reveal the selected keys otherwise
        assert!(com.submissions[0].vecs[0].iter().any(|&x| x > (1u64 << 30)));
        assert!(com.submissions[0].counts[0].iter().any(|&x| x > (1u64 << 30)));
        // a single-member committee has no peers, hence no masks
        let mut solo = SecAggCommittee::new(&store, vec![4], 99);
        solo.submit(4, &spec, &[vec![0]], &ups).unwrap();
        assert!(solo.submissions[0].vecs[0].iter().all(|&x| x == 0));
    }

    #[test]
    fn committee_rejects_non_members_and_charges_group_bytes() {
        let (store, spec) = setup();
        let mut com = SecAggCommittee::new(&store, vec![1, 2], 7);
        let ups = vec![vec![0.0; 50], vec![0.0; 50]];
        assert!(com.submit(8, &spec, &[vec![0]], &ups).is_err());
        assert_eq!(
            com.up_bytes_per_client,
            store.num_params() as u64 * 16,
            "masked update + masked counts, 8 bytes per u64 group element"
        );
    }

    #[test]
    fn two_committees_with_different_seeds_mask_differently() {
        let (store, spec) = setup();
        let ups = vec![vec![1.0; 50], vec![1.0; 50]];
        let mut a = SecAggCommittee::new(&store, vec![1, 2], 1000);
        let mut b = SecAggCommittee::new(&store, vec![1, 2], 1001);
        a.submit(1, &spec, &[vec![0]], &ups).unwrap();
        b.submit(1, &spec, &[vec![0]], &ups).unwrap();
        assert_ne!(
            a.submissions[0].vecs[0], b.submissions[0].vecs[0],
            "close-group re-keying must rotate mask material"
        );
        // ...but each still unmasks to the same (exact) sum once its peer
        // is recovered
        a.mark_dropped(2);
        b.mark_dropped(2);
        let (sa, _) = a.unmask_sum();
        let (sb, _) = b.unmask_sum();
        assert_stores_bit_equal(&sa, &sb, "seed-independent unmasked sum");
    }
}

//! Invertible Bloom Lookup Table for sparse secure aggregation (paper §4.2,
//! citing Bell et al. 2020).
//!
//! Clients encode their sparse `(key, value-vector)` updates into a
//! fixed-size table; tables are *linear* (cell-wise addable), so an
//! aggregator — or a secure-aggregation protocol operating on the table as a
//! dense vector — can sum client tables without seeing which keys each
//! client contributed. Decoding the summed table by peeling recovers the
//! per-key summed values, provided the number of *distinct* keys stays under
//! the table's capacity.
//!
//! Cells hold (count, key_sum, key_hash_sum, value_sum). A cell is *pure*
//! when it contains `c` copies of a single key `k`: `key_sum == c*k` and
//! `key_hash_sum == c*h(k)`. Peeling subtracts pure cells until the table
//! drains (success) or stalls (capacity exceeded).

/// Number of hash partitions (standard IBLT uses 3-4).
const HASHES: usize = 3;

fn key_hash(key: u64) -> u64 {
    // Must be strongly non-linear: purity checks compare Σ h(k_i) against
    // c·h(k'), and a multiplicative (near-linear) hash admits phantom keys
    // k' = (k1+k2)/2 with h(k1)+h(k2) == 2·h(k'), corrupting the peel.
    mix64(key ^ 0xD6E8FEB86659FD93) | 1
}

fn mix64(mut x: u64) -> u64 {
    // splitmix64 finalizer: full avalanche so correlated keys never share
    // cell triples across partitions.
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D049BB133111EB);
    x ^= x >> 31;
    x
}

fn cell_index(key: u64, part: usize, cells_per_part: usize, salt: u64) -> usize {
    let h = mix64(key ^ salt.rotate_left(21 * part as u32 + 7) ^ ((part as u64 + 1) << 56));
    part * cells_per_part + (h % cells_per_part as u64) as usize
}

#[derive(Clone, Debug, Default)]
struct Cell {
    count: i64,
    key_sum: i128,
    key_hash_sum: i128,
    value_sum: Vec<f32>,
}

impl Cell {
    fn new(dim: usize) -> Self {
        Cell {
            count: 0,
            key_sum: 0,
            key_hash_sum: 0,
            value_sum: vec![0.0; dim],
        }
    }

    fn is_pure(&self) -> Option<u64> {
        if self.count <= 0 {
            return None;
        }
        let c = self.count as i128;
        if self.key_sum % c != 0 {
            return None;
        }
        let k = self.key_sum / c;
        if k < 0 || k > u64::MAX as i128 {
            return None;
        }
        let k = k as u64;
        if self.key_hash_sum == c * key_hash(k) as i128 {
            Some(k)
        } else {
            None
        }
    }
}

/// Additive IBLT over `(u64 key, [f32; dim] value)` entries.
#[derive(Clone, Debug)]
pub struct Iblt {
    cells_per_part: usize,
    dim: usize,
    salt: u64,
    cells: Vec<Cell>,
}

impl Iblt {
    /// `capacity`: max distinct keys expected to decode reliably. The table
    /// allocates ~2.5 cells per key per hash partition — generous vs the
    /// asymptotic ~1.3 threshold for 3-partition IBLTs, because small tables
    /// (hundreds of keys, the FedSelect regime) sit far from the asymptotic
    /// regime and 2-cycles otherwise stall peeling with small probability.
    pub fn new(capacity: usize, dim: usize, salt: u64) -> Self {
        let cells_per_part = ((capacity as f64 * 2.5).ceil() as usize).max(8);
        Iblt {
            cells_per_part,
            dim,
            salt,
            cells: (0..cells_per_part * HASHES).map(|_| Cell::new(dim)).collect(),
        }
    }

    pub fn insert(&mut self, key: u64, value: &[f32]) {
        assert_eq!(value.len(), self.dim);
        for part in 0..HASHES {
            let i = cell_index(key, part, self.cells_per_part, self.salt);
            let c = &mut self.cells[i];
            c.count += 1;
            c.key_sum += key as i128;
            c.key_hash_sum += key_hash(key) as i128;
            for (v, &x) in c.value_sum.iter_mut().zip(value.iter()) {
                *v += x;
            }
        }
    }

    /// Cell-wise merge (the linearity secure aggregation relies on).
    pub fn merge(&mut self, other: &Iblt) {
        assert_eq!(self.cells_per_part, other.cells_per_part);
        assert_eq!(self.dim, other.dim);
        assert_eq!(self.salt, other.salt);
        for (a, b) in self.cells.iter_mut().zip(other.cells.iter()) {
            a.count += b.count;
            a.key_sum += b.key_sum;
            a.key_hash_sum += b.key_hash_sum;
            for (v, &x) in a.value_sum.iter_mut().zip(b.value_sum.iter()) {
                *v += x;
            }
        }
    }

    /// Serialized size in bytes (what a client would upload).
    pub fn wire_bytes(&self) -> u64 {
        // count(8) + key_sum(16) + key_hash_sum(16) + dim * 4
        (self.cells.len() * (8 + 16 + 16 + self.dim * 4)) as u64
    }

    /// Residual nonzero cells (diagnostics): (index, count, key_sum).
    pub fn residual_cells(&self) -> Vec<(usize, i64, i128)> {
        self.cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.count != 0)
            .map(|(i, c)| (i, c.count, c.key_sum))
            .collect()
    }

    /// Cell triple a key hashes to (diagnostics).
    pub fn cells_of(&self, key: u64) -> [usize; HASHES] {
        let mut out = [0usize; HASHES];
        for (p, o) in out.iter_mut().enumerate() {
            *o = cell_index(key, p, self.cells_per_part, self.salt);
        }
        out
    }

    /// Peel the table. Returns `Ok(entries)` with per-key summed values
    /// (and, per key, the number of inserts `count`), or `Err(residual)`
    /// with the number of undecoded cells if peeling stalls.
    pub fn decode(mut self) -> Result<Vec<(u64, i64, Vec<f32>)>, usize> {
        let mut out: std::collections::HashMap<u64, (i64, Vec<f32>)> =
            std::collections::HashMap::new();
        loop {
            let mut progressed = false;
            for i in 0..self.cells.len() {
                let Some(k) = self.cells[i].is_pure() else {
                    continue;
                };
                let c = self.cells[i].count;
                let vals = self.cells[i].value_sum.clone();
                // remove c copies of k (with value sum `vals`) everywhere
                for part in 0..HASHES {
                    let j = cell_index(k, part, self.cells_per_part, self.salt);
                    let cell = &mut self.cells[j];
                    cell.count -= c;
                    cell.key_sum -= c as i128 * k as i128;
                    cell.key_hash_sum -= c as i128 * key_hash(k) as i128;
                    for (v, &x) in cell.value_sum.iter_mut().zip(vals.iter()) {
                        *v -= x;
                    }
                }
                let e = out.entry(k).or_insert_with(|| (0, vec![0.0; self.dim]));
                e.0 += c;
                for (v, &x) in e.1.iter_mut().zip(vals.iter()) {
                    *v += x;
                }
                progressed = true;
            }
            if !progressed {
                break;
            }
        }
        let residual = self.cells.iter().filter(|c| c.count != 0).count();
        if residual == 0 {
            let mut v: Vec<(u64, i64, Vec<f32>)> =
                out.into_iter().map(|(k, (c, val))| (k, c, val)).collect();
            v.sort_by_key(|e| e.0);
            Ok(v)
        } else {
            Err(residual)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_table_roundtrip() {
        let mut t = Iblt::new(32, 3, 1);
        t.insert(5, &[1.0, 2.0, 3.0]);
        t.insert(900, &[0.5, 0.5, 0.5]);
        let got = t.decode().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], (5, 1, vec![1.0, 2.0, 3.0]));
        assert_eq!(got[1].0, 900);
    }

    #[test]
    fn merged_tables_sum_overlapping_keys() {
        let mut a = Iblt::new(64, 2, 9);
        let mut b = Iblt::new(64, 2, 9);
        a.insert(7, &[1.0, 0.0]);
        a.insert(13, &[2.0, 2.0]);
        b.insert(7, &[3.0, 1.0]);
        b.insert(21, &[1.0, 1.0]);
        a.merge(&b);
        let got = a.decode().unwrap();
        let map: std::collections::HashMap<u64, (i64, Vec<f32>)> =
            got.into_iter().map(|(k, c, v)| (k, (c, v))).collect();
        assert_eq!(map[&7], (2, vec![4.0, 1.0]));
        assert_eq!(map[&13], (1, vec![2.0, 2.0]));
        assert_eq!(map[&21], (1, vec![1.0, 1.0]));
    }

    #[test]
    fn many_clients_many_keys_decode() {
        let dim = 4;
        let mut total = Iblt::new(300, dim, 3);
        let mut expect: std::collections::HashMap<u64, Vec<f32>> = Default::default();
        for client in 0..20u64 {
            let mut t = Iblt::new(300, dim, 3);
            for j in 0..10u64 {
                let key = (client * 7 + j * 13) % 200;
                let val = vec![client as f32 + 1.0; dim];
                t.insert(key, &val);
                let e = expect.entry(key).or_insert_with(|| vec![0.0; dim]);
                for (a, b) in e.iter_mut().zip(val.iter()) {
                    *a += b;
                }
            }
            total.merge(&t);
        }
        let got = total.decode().unwrap();
        assert_eq!(got.len(), expect.len());
        for (k, _, v) in got {
            let e = &expect[&k];
            for (a, b) in v.iter().zip(e.iter()) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn overload_fails_loud_not_wrong() {
        let mut t = Iblt::new(4, 1, 5);
        for k in 0..200u64 {
            t.insert(k, &[1.0]);
        }
        assert!(t.decode().is_err());
    }
}

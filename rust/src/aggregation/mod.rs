//! `AGGREGATE*` — aggregation with deselection (paper §4, eq. 5), plus the
//! privacy-preserving aggregation substrates of §4.2.
//!
//! [`SparseAccumulator`] implements
//! `AGGREGATE*_mean({u_n}@C, {z_n}@C, φ) = (1/N) Σ φ(u_n, z_n)` —
//! clients' sliced updates are scattered into full model space via the
//! model's [`SelectSpec`] and averaged. Two averaging semantics:
//!
//! * [`AggMode::CohortMean`] — divide by cohort size N (the paper's eq. 5;
//!   with all-keys clients this is exactly dense FedAvg averaging).
//! * [`AggMode::PerCoordMean`] — divide each coordinate by its selection
//!   count (an ablation: see `bench_aggregation`).
//!
//! [`secure`] simulates the pairwise-mask Secure Aggregation protocol —
//! whole-cohort float masks ([`SecureAggSim`], synchronous barrier only)
//! and close-group fixed-point committees ([`SecAggCommittee`], exact
//! cancellation in `Z_2^64`, composing with goal-count closes) — and
//! [`iblt`] provides the invertible-Bloom-lookup-table sparse aggregation
//! the paper cites (Bell et al. 2020) for private *sparse* sums.

pub mod iblt;
pub mod secure;

pub use secure::{fp_dequantize, fp_quantize, SecAggCommittee, SecureAggSim};

use crate::error::Result;
use crate::model::{ParamStore, SelectSpec};

/// Which `(keyspace, key)` rows an aggregation pass actually wrote — the
/// union of the merged updates' select keys. This is what the cross-round
/// slice cache's [`VersionClock`](crate::cache::VersionClock) bumps on: a
/// row outside this set was not written, so every client's cached copy of
/// it stays valid. Sets are ordered (`BTreeSet`) so iteration — and hence
/// version bumping — is deterministic.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TouchedKeys {
    per_keyspace: Vec<std::collections::BTreeSet<u32>>,
}

impl TouchedKeys {
    pub fn new(num_keyspaces: usize) -> Self {
        TouchedKeys {
            per_keyspace: vec![std::collections::BTreeSet::new(); num_keyspaces],
        }
    }

    /// Record one client's select keys (grown on demand if the keyspace
    /// count was not known up front).
    pub fn record(&mut self, keys: &[Vec<u32>]) {
        if self.per_keyspace.len() < keys.len() {
            self.per_keyspace
                .resize_with(keys.len(), std::collections::BTreeSet::new);
        }
        for (ks, kk) in keys.iter().enumerate() {
            self.per_keyspace[ks].extend(kk.iter().copied());
        }
    }

    /// Record a single touched key.
    pub fn record_one(&mut self, keyspace: usize, key: u32) {
        if self.per_keyspace.len() <= keyspace {
            self.per_keyspace
                .resize_with(keyspace + 1, std::collections::BTreeSet::new);
        }
        self.per_keyspace[keyspace].insert(key);
    }

    /// Touched keys per keyspace, in keyspace order (each set ascending).
    pub fn keyspaces(&self) -> impl Iterator<Item = &std::collections::BTreeSet<u32>> {
        self.per_keyspace.iter()
    }

    /// Distinct touched keys in one keyspace.
    pub fn count_in(&self, keyspace: usize) -> usize {
        self.per_keyspace.get(keyspace).map_or(0, |s| s.len())
    }

    /// Distinct touched keys across all keyspaces.
    pub fn count(&self) -> usize {
        self.per_keyspace.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    pub fn contains(&self, keyspace: usize, key: u32) -> bool {
        self.per_keyspace
            .get(keyspace)
            .is_some_and(|s| s.contains(&key))
    }

    /// Union another touched set into this one (keyspace-wise) — the
    /// committee SecAgg path merges one set per close committee.
    pub fn merge(&mut self, other: &TouchedKeys) {
        if self.per_keyspace.len() < other.per_keyspace.len() {
            self.per_keyspace
                .resize_with(other.per_keyspace.len(), std::collections::BTreeSet::new);
        }
        for (mine, theirs) in self.per_keyspace.iter_mut().zip(other.per_keyspace.iter()) {
            mine.extend(theirs.iter().copied());
        }
    }
}

/// Averaging semantics for `AGGREGATE*`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggMode {
    /// (1/N) Σ φ(u_n, z_n) — the paper's eq. (5).
    CohortMean,
    /// Per-coordinate mean over the clients that selected that coordinate.
    PerCoordMean,
}

impl std::str::FromStr for AggMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "cohort" | "cohort-mean" => Ok(AggMode::CohortMean),
            "per-coord" | "per-coord-mean" => Ok(AggMode::PerCoordMean),
            other => Err(format!("unknown agg mode {other:?}")),
        }
    }
}

/// Generic aggregator interface (dense or sparse).
pub trait Aggregator {
    /// Absorb one client's update (sliced tensors in binding order).
    fn add_client(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()>;

    /// Absorb one client's update scaled by `weight` — the buffered
    /// (FedBuff-style) round engine discounts stale updates with
    /// `1/sqrt(1+staleness)`. `weight == 1.0` MUST take the exact
    /// [`Aggregator::add_client`] float path, so synchronous aggregation
    /// through this entry point stays byte-identical. Aggregators whose
    /// algebra cannot scale per client (pairwise-mask secure aggregation:
    /// unequal scales stop the masks cancelling) reject `weight != 1.0`.
    fn add_client_weighted(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
        weight: f32,
    ) -> Result<()>;

    /// Produce the server update `u` in full model space, paired with the
    /// [`TouchedKeys`] of the merged updates — the `(keyspace, key)` rows
    /// the aggregation pass could have written. Returning the touched set
    /// here (instead of having the trainer re-union the merge set's keys)
    /// keeps the version-clock bump a pure consumer of the aggregator.
    fn finalize(self: Box<Self>, mode: AggMode) -> (ParamStore, TouchedKeys);

    fn num_clients(&self) -> usize;
}

/// Plain (trusted-server) sparse accumulator.
pub struct SparseAccumulator {
    acc: ParamStore,
    counts: ParamStore,
    clients: usize,
    touched: TouchedKeys,
    /// bytes a client uploads: sliced update + its keys
    pub up_bytes: u64,
}

impl SparseAccumulator {
    pub fn new(store: &ParamStore) -> Self {
        SparseAccumulator {
            acc: store.zeros_like(),
            counts: store.zeros_like(),
            clients: 0,
            touched: TouchedKeys::default(),
            up_bytes: 0,
        }
    }

    /// Direct access for tests / secure-agg comparison.
    pub fn raw(&self) -> (&ParamStore, &ParamStore) {
        (&self.acc, &self.counts)
    }

    /// The rows written so far (union of absorbed clients' keys) — what the
    /// slice cache's version clock bumps after the close.
    pub fn touched(&self) -> &TouchedKeys {
        &self.touched
    }
}

impl Aggregator for SparseAccumulator {
    fn add_client(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        spec.deselect_add(&mut self.acc, &mut self.counts, keys, updates)?;
        self.clients += 1;
        self.touched.record(keys);
        self.up_bytes += updates.iter().map(|u| u.len() as u64 * 4).sum::<u64>()
            + keys.iter().map(|k| k.len() as u64 * 4).sum::<u64>();
        Ok(())
    }

    fn add_client_weighted(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
        weight: f32,
    ) -> Result<()> {
        if weight == 1.0 {
            // exact unweighted float path — synchronous aggregation through
            // the round engine stays byte-identical to the legacy loop
            return self.add_client(spec, keys, updates);
        }
        let scaled: Vec<Vec<f32>> = updates
            .iter()
            .map(|u| u.iter().map(|&v| v * weight).collect())
            .collect();
        spec.deselect_add(&mut self.acc, &mut self.counts, keys, &scaled)?;
        self.clients += 1;
        self.touched.record(keys);
        // the client uploaded the unscaled update; the discount is server-side
        self.up_bytes += updates.iter().map(|u| u.len() as u64 * 4).sum::<u64>()
            + keys.iter().map(|k| k.len() as u64 * 4).sum::<u64>();
        Ok(())
    }

    fn finalize(self: Box<Self>, mode: AggMode) -> (ParamStore, TouchedKeys) {
        (
            finalize_mean(self.acc, &self.counts, self.clients, mode),
            self.touched,
        )
    }

    fn num_clients(&self) -> usize {
        self.clients
    }
}

pub(crate) fn finalize_mean(
    mut acc: ParamStore,
    counts: &ParamStore,
    clients: usize,
    mode: AggMode,
) -> ParamStore {
    match mode {
        AggMode::CohortMean => {
            let n = (clients.max(1)) as f32;
            for seg in &mut acc.segments {
                for v in &mut seg.data {
                    *v /= n;
                }
            }
        }
        AggMode::PerCoordMean => {
            for (seg, cseg) in acc.segments.iter_mut().zip(counts.segments.iter()) {
                for (v, &c) in seg.data.iter_mut().zip(cseg.data.iter()) {
                    if c > 0.0 {
                        *v /= c;
                    }
                }
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    fn setup() -> (ParamStore, SelectSpec) {
        let arch = ModelArch::logreg(8);
        let store = arch.init_store(&mut Rng::new(4, 0));
        (store.clone(), arch.select_spec())
    }

    #[test]
    fn cohort_mean_with_all_keys_equals_dense_fedavg() {
        let (store, spec) = setup();
        let all: Vec<u32> = (0..8).collect();
        let mut agg = Box::new(SparseAccumulator::new(&store));
        // two clients, updates = all ones and all twos
        for v in [1.0f32, 2.0] {
            let ups = vec![vec![v; 8 * 50], vec![v; 50]];
            agg.add_client(&spec, &[all.clone()], &ups).unwrap();
        }
        let (u, touched) = agg.finalize(AggMode::CohortMean);
        assert!(u.segments[0].data.iter().all(|&x| (x - 1.5).abs() < 1e-6));
        assert!(u.segments[1].data.iter().all(|&x| (x - 1.5).abs() < 1e-6));
        // finalize hands the trainer the merge set's touched rows directly
        assert_eq!(touched.count_in(0), 8);
    }

    #[test]
    fn cohort_vs_per_coord_on_disjoint_keys() {
        let (store, spec) = setup();
        let mut agg = Box::new(SparseAccumulator::new(&store));
        // client A selects row 0, client B selects row 1
        agg.add_client(&spec, &[vec![0]], &[vec![3.0; 50], vec![0.0; 50]])
            .unwrap();
        agg.add_client(&spec, &[vec![1]], &[vec![5.0; 50], vec![0.0; 50]])
            .unwrap();
        let (acc, counts) = agg.raw();
        assert_eq!(acc.segments[0].data[0], 3.0);
        assert_eq!(counts.segments[0].data[0], 1.0);
        let (u_cohort, _) = Box::new(SparseAccumulator {
            acc: acc.clone(),
            counts: counts.clone(),
            clients: 2,
            touched: TouchedKeys::default(),
            up_bytes: 0,
        })
        .finalize(AggMode::CohortMean);
        // cohort mean divides by N=2 even though each row was touched once
        assert_eq!(u_cohort.segments[0].data[0], 1.5);
        assert_eq!(u_cohort.segments[0].data[50], 2.5);
        let (u_coord, _) = Box::new(SparseAccumulator {
            acc: acc.clone(),
            counts: counts.clone(),
            clients: 2,
            touched: TouchedKeys::default(),
            up_bytes: 0,
        })
        .finalize(AggMode::PerCoordMean);
        assert_eq!(u_coord.segments[0].data[0], 3.0);
        assert_eq!(u_coord.segments[0].data[50], 5.0);
        // untouched rows stay zero under both
        assert_eq!(u_cohort.segments[0].data[100], 0.0);
        assert_eq!(u_coord.segments[0].data[100], 0.0);
    }

    #[test]
    fn weighted_add_scales_the_update_but_not_the_ledger() {
        let (store, spec) = setup();
        let mut plain = Box::new(SparseAccumulator::new(&store));
        let mut half = Box::new(SparseAccumulator::new(&store));
        let ups = vec![vec![2.0f32; 100], vec![2.0; 50]];
        let keys = vec![vec![0u32, 3]];
        plain.add_client(&spec, &keys, &ups).unwrap();
        half.add_client_weighted(&spec, &keys, &ups, 0.5).unwrap();
        assert_eq!(plain.up_bytes, half.up_bytes);
        let (pa, _) = plain.raw();
        let (ha, _) = half.raw();
        for (ps, hs) in pa.segments.iter().zip(ha.segments.iter()) {
            for (p, h) in ps.data.iter().zip(hs.data.iter()) {
                assert_eq!(*h, 0.5 * *p);
            }
        }
        // weight 1.0 routes through the exact unweighted path
        let mut a = Box::new(SparseAccumulator::new(&store));
        let mut b = Box::new(SparseAccumulator::new(&store));
        a.add_client(&spec, &keys, &ups).unwrap();
        b.add_client_weighted(&spec, &keys, &ups, 1.0).unwrap();
        for (sa, sb) in a.raw().0.segments.iter().zip(b.raw().0.segments.iter()) {
            for (x, y) in sa.data.iter().zip(sb.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn touched_keys_report_the_union_of_absorbed_clients() {
        let (store, spec) = setup();
        let mut agg = Box::new(SparseAccumulator::new(&store));
        assert!(agg.touched().is_empty());
        agg.add_client(&spec, &[vec![0, 3]], &[vec![1.0; 100], vec![1.0; 50]])
            .unwrap();
        agg.add_client_weighted(&spec, &[vec![3, 5]], &[vec![1.0; 100], vec![1.0; 50]], 0.5)
            .unwrap();
        let t = agg.touched();
        assert_eq!(t.count(), 3);
        assert_eq!(t.count_in(0), 3);
        for k in [0u32, 3, 5] {
            assert!(t.contains(0, k));
        }
        assert!(!t.contains(0, 1), "unselected rows are untouched");
        assert!(!t.contains(7, 0), "unknown keyspace is empty");
        // deterministic ascending iteration per keyspace
        let seen: Vec<u32> = t.keyspaces().next().unwrap().iter().copied().collect();
        assert_eq!(seen, vec![0, 3, 5]);
    }

    #[test]
    fn touched_keys_merge_unions_keyspace_wise() {
        let mut a = TouchedKeys::new(1);
        a.record(&[vec![1, 3]]);
        let mut b = TouchedKeys::new(2);
        b.record(&[vec![3, 5], vec![0]]);
        a.merge(&b);
        assert_eq!(a.count_in(0), 3);
        assert_eq!(a.count_in(1), 1);
        for k in [1u32, 3, 5] {
            assert!(a.contains(0, k));
        }
        assert!(a.contains(1, 0));
    }

    #[test]
    fn up_bytes_track_slice_plus_keys() {
        let (store, spec) = setup();
        let mut agg = Box::new(SparseAccumulator::new(&store));
        agg.add_client(&spec, &[vec![0, 3]], &[vec![0.0; 100], vec![0.0; 50]])
            .unwrap();
        assert_eq!(agg.up_bytes, (150 * 4 + 2 * 4) as u64);
    }
}

//! `AGGREGATE*` — aggregation with deselection (paper §4, eq. 5), plus the
//! privacy-preserving aggregation substrates of §4.2.
//!
//! [`SparseAccumulator`] implements
//! `AGGREGATE*_mean({u_n}@C, {z_n}@C, φ) = (1/N) Σ φ(u_n, z_n)` —
//! clients' sliced updates are scattered into full model space via the
//! model's [`SelectSpec`] and averaged. Two averaging semantics:
//!
//! * [`AggMode::CohortMean`] — divide by cohort size N (the paper's eq. 5;
//!   with all-keys clients this is exactly dense FedAvg averaging).
//! * [`AggMode::PerCoordMean`] — divide each coordinate by its selection
//!   count (an ablation: see `bench_aggregation`).
//!
//! [`ShardedAccumulator`] is the same algebra striped by key range: the
//! flat coordinate space of every segment is split into contiguous shards
//! and one scatter-add is applied by `shards` scoped threads in parallel,
//! each owning its stripe exclusively (no locks on the hot path). Because
//! the stripes partition coordinates, the per-coordinate float-add order
//! is identical to the sequential scatter at any shard count — the sharded
//! accumulator is bit-exact against [`SparseAccumulator`] (test-enforced);
//! what changes is only the wall time the round's close stalls on merging.
//! The `--exec fast` pipeline selects it; see [`crate::exec`].
//!
//! [`secure`] simulates the pairwise-mask Secure Aggregation protocol —
//! whole-cohort float masks ([`SecureAggSim`], synchronous barrier only)
//! and close-group fixed-point committees ([`SecAggCommittee`], exact
//! cancellation in `Z_2^64`, composing with goal-count closes) — and
//! [`iblt`] provides the invertible-Bloom-lookup-table sparse aggregation
//! the paper cites (Bell et al. 2020) for private *sparse* sums.

pub mod iblt;
pub mod secure;

pub use secure::{fp_dequantize, fp_quantize, SecAggCommittee, SecureAggSim};

use crate::error::Result;
use crate::model::{Binding, ParamStore, SelectSpec};

/// Which `(keyspace, key)` rows an aggregation pass actually wrote — the
/// union of the merged updates' select keys. This is what the cross-round
/// slice cache's [`VersionClock`](crate::cache::VersionClock) bumps on: a
/// row outside this set was not written, so every client's cached copy of
/// it stays valid. Sets are ordered (`BTreeSet`) so iteration — and hence
/// version bumping — is deterministic.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TouchedKeys {
    per_keyspace: Vec<std::collections::BTreeSet<u32>>,
}

impl TouchedKeys {
    pub fn new(num_keyspaces: usize) -> Self {
        TouchedKeys {
            per_keyspace: vec![std::collections::BTreeSet::new(); num_keyspaces],
        }
    }

    /// Record one client's select keys (grown on demand if the keyspace
    /// count was not known up front).
    pub fn record(&mut self, keys: &[Vec<u32>]) {
        if self.per_keyspace.len() < keys.len() {
            self.per_keyspace
                .resize_with(keys.len(), std::collections::BTreeSet::new);
        }
        for (ks, kk) in keys.iter().enumerate() {
            self.per_keyspace[ks].extend(kk.iter().copied());
        }
    }

    /// Record a single touched key.
    pub fn record_one(&mut self, keyspace: usize, key: u32) {
        if self.per_keyspace.len() <= keyspace {
            self.per_keyspace
                .resize_with(keyspace + 1, std::collections::BTreeSet::new);
        }
        self.per_keyspace[keyspace].insert(key);
    }

    /// Touched keys per keyspace, in keyspace order (each set ascending).
    pub fn keyspaces(&self) -> impl Iterator<Item = &std::collections::BTreeSet<u32>> {
        self.per_keyspace.iter()
    }

    /// Distinct touched keys in one keyspace.
    pub fn count_in(&self, keyspace: usize) -> usize {
        self.per_keyspace.get(keyspace).map_or(0, |s| s.len())
    }

    /// Distinct touched keys across all keyspaces.
    pub fn count(&self) -> usize {
        self.per_keyspace.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    pub fn contains(&self, keyspace: usize, key: u32) -> bool {
        self.per_keyspace
            .get(keyspace)
            .is_some_and(|s| s.contains(&key))
    }

    /// Union another touched set into this one (keyspace-wise) — the
    /// committee SecAgg path merges one set per close committee.
    pub fn merge(&mut self, other: &TouchedKeys) {
        if self.per_keyspace.len() < other.per_keyspace.len() {
            self.per_keyspace
                .resize_with(other.per_keyspace.len(), std::collections::BTreeSet::new);
        }
        for (mine, theirs) in self.per_keyspace.iter_mut().zip(other.per_keyspace.iter()) {
            mine.extend(theirs.iter().copied());
        }
    }
}

/// Averaging semantics for `AGGREGATE*`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggMode {
    /// (1/N) Σ φ(u_n, z_n) — the paper's eq. (5).
    CohortMean,
    /// Per-coordinate mean over the clients that selected that coordinate.
    PerCoordMean,
}

impl std::str::FromStr for AggMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "cohort" | "cohort-mean" => Ok(AggMode::CohortMean),
            "per-coord" | "per-coord-mean" => Ok(AggMode::PerCoordMean),
            other => Err(format!("unknown agg mode {other:?}")),
        }
    }
}

/// Generic aggregator interface (dense or sparse).
pub trait Aggregator {
    /// Absorb one client's update (sliced tensors in binding order).
    fn add_client(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()>;

    /// Absorb one client's update scaled by `weight` — the buffered
    /// (FedBuff-style) round engine discounts stale updates with
    /// `1/sqrt(1+staleness)`. `weight == 1.0` MUST take the exact
    /// [`Aggregator::add_client`] float path, so synchronous aggregation
    /// through this entry point stays byte-identical. Aggregators whose
    /// algebra cannot scale per client (pairwise-mask secure aggregation:
    /// unequal scales stop the masks cancelling) reject `weight != 1.0`.
    fn add_client_weighted(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
        weight: f32,
    ) -> Result<()>;

    /// Produce the server update `u` in full model space, paired with the
    /// [`TouchedKeys`] of the merged updates — the `(keyspace, key)` rows
    /// the aggregation pass could have written. Returning the touched set
    /// here (instead of having the trainer re-union the merge set's keys)
    /// keeps the version-clock bump a pure consumer of the aggregator.
    fn finalize(self: Box<Self>, mode: AggMode) -> (ParamStore, TouchedKeys);

    fn num_clients(&self) -> usize;
}

/// Plain (trusted-server) sparse accumulator.
pub struct SparseAccumulator {
    acc: ParamStore,
    counts: ParamStore,
    clients: usize,
    touched: TouchedKeys,
    /// bytes a client uploads: sliced update + its keys
    pub up_bytes: u64,
}

impl SparseAccumulator {
    pub fn new(store: &ParamStore) -> Self {
        SparseAccumulator {
            acc: store.zeros_like(),
            counts: store.zeros_like(),
            clients: 0,
            touched: TouchedKeys::default(),
            up_bytes: 0,
        }
    }

    /// Direct access for tests / secure-agg comparison.
    pub fn raw(&self) -> (&ParamStore, &ParamStore) {
        (&self.acc, &self.counts)
    }

    /// The rows written so far (union of absorbed clients' keys) — what the
    /// slice cache's version clock bumps after the close.
    pub fn touched(&self) -> &TouchedKeys {
        &self.touched
    }
}

impl Aggregator for SparseAccumulator {
    fn add_client(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        spec.deselect_add(&mut self.acc, &mut self.counts, keys, updates)?;
        self.clients += 1;
        self.touched.record(keys);
        self.up_bytes += updates.iter().map(|u| u.len() as u64 * 4).sum::<u64>()
            + keys.iter().map(|k| k.len() as u64 * 4).sum::<u64>();
        Ok(())
    }

    fn add_client_weighted(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
        weight: f32,
    ) -> Result<()> {
        if weight == 1.0 {
            // exact unweighted float path — synchronous aggregation through
            // the round engine stays byte-identical to the legacy loop
            return self.add_client(spec, keys, updates);
        }
        let scaled: Vec<Vec<f32>> = updates
            .iter()
            .map(|u| u.iter().map(|&v| v * weight).collect())
            .collect();
        spec.deselect_add(&mut self.acc, &mut self.counts, keys, &scaled)?;
        self.clients += 1;
        self.touched.record(keys);
        // the client uploaded the unscaled update; the discount is server-side
        self.up_bytes += updates.iter().map(|u| u.len() as u64 * 4).sum::<u64>()
            + keys.iter().map(|k| k.len() as u64 * 4).sum::<u64>();
        Ok(())
    }

    fn finalize(self: Box<Self>, mode: AggMode) -> (ParamStore, TouchedKeys) {
        (
            finalize_mean(self.acc, &self.counts, self.clients, mode),
            self.touched,
        )
    }

    fn num_clients(&self) -> usize {
        self.clients
    }
}

/// Key-striped accumulator: [`SparseAccumulator`]'s algebra with every
/// scatter-add applied in parallel by `shards` scoped threads, each owning
/// a contiguous stripe of every segment's flat coordinate space.
///
/// # Bit-exactness
///
/// The stripes *partition* coordinates, so each coordinate is written by
/// exactly one shard and receives exactly the adds the sequential scatter
/// would apply, in the same order (clients are absorbed one
/// `add_client*` call at a time; within a call each coordinate is touched
/// at most once per key occurrence, iterated in the same `(group, key)`
/// order as [`SelectSpec::deselect_add`]). Float addition order per
/// coordinate is therefore independent of the shard count, and the
/// accumulator state is bit-identical to [`SparseAccumulator`] fed the
/// same sequence — enforced by `sharded_accumulator_is_bit_exact`.
///
/// Small updates (< [`ShardedAccumulator::PARALLEL_FLOOR`] floats) are
/// applied inline: spawning threads would cost more than the scatter.
pub struct ShardedAccumulator {
    acc: ParamStore,
    counts: ParamStore,
    clients: usize,
    touched: TouchedKeys,
    /// bytes a client uploads: sliced update + its keys
    pub up_bytes: u64,
    shards: usize,
}

impl ShardedAccumulator {
    /// Below this many update floats a scatter runs inline on the caller
    /// thread (identical math, no spawns).
    pub const PARALLEL_FLOOR: usize = 1 << 15;

    /// `shards` is clamped to [1, 64]; 1 degenerates to the sequential
    /// scatter (still bit-exact, just without the stripe parallelism).
    pub fn new(store: &ParamStore, shards: usize) -> Self {
        ShardedAccumulator {
            acc: store.zeros_like(),
            counts: store.zeros_like(),
            clients: 0,
            touched: TouchedKeys::default(),
            up_bytes: 0,
            shards: shards.clamp(1, 64),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Direct access for tests / bit-exactness comparison.
    pub fn raw(&self) -> (&ParamStore, &ParamStore) {
        (&self.acc, &self.counts)
    }

    pub fn touched(&self) -> &TouchedKeys {
        &self.touched
    }

    /// Validate one client's update shapes — the same errors
    /// [`SelectSpec::deselect_add`] raises, checked up front so the
    /// parallel scatter never observes a malformed update.
    fn validate(
        &self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        if updates.len() != spec.bindings.len() {
            return Err(crate::error::Error::Shape(format!(
                "expected {} update tensors, got {}",
                spec.bindings.len(),
                updates.len()
            )));
        }
        for (b, upd) in spec.bindings.iter().zip(updates.iter()) {
            match b {
                Binding::Full { seg } => {
                    let len = self.acc.segments[*seg].data.len();
                    if upd.len() != len {
                        return Err(crate::error::Error::Shape(format!(
                            "dense update len {} != segment len {len}",
                            upd.len()
                        )));
                    }
                }
                Binding::Keyed { keyspace, map, .. } => {
                    let ks_keys = keys.get(*keyspace).ok_or_else(|| {
                        crate::error::Error::Shape(format!(
                            "missing keys for keyspace {keyspace}"
                        ))
                    })?;
                    if upd.len() != map.sliced_len(ks_keys.len()) {
                        return Err(crate::error::Error::Shape(format!(
                            "keyed update len {} != sliced len {}",
                            upd.len(),
                            map.sliced_len(ks_keys.len())
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Scatter one (possibly weighted) update into the stripes. `weight ==
    /// 1.0` adds the raw floats (the exact unweighted path); other weights
    /// scale each addend as it lands, which is the same `u * w` the
    /// sequential weighted path feeds `deselect_add`.
    fn add_scaled(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
        weight: f32,
    ) -> Result<()> {
        self.validate(spec, keys, updates)?;
        let total_floats: usize = updates.iter().map(Vec::len).sum();
        let shards = if total_floats < Self::PARALLEL_FLOOR {
            1
        } else {
            self.shards
        };
        if shards <= 1 {
            let nseg = self.acc.segments.len();
            let mut stripe = Vec::with_capacity(nseg);
            for (aseg, cseg) in self
                .acc
                .segments
                .iter_mut()
                .zip(self.counts.segments.iter_mut())
            {
                stripe.push((0usize, &mut aseg.data[..], &mut cseg.data[..]));
            }
            apply_stripe(spec, keys, updates, weight, stripe);
        } else {
            // Split every segment (and its counts) into `shards` contiguous
            // stripes; stripe j of every segment goes to thread j.
            let nseg = self.acc.segments.len();
            let mut stripes: Vec<Vec<(usize, &mut [f32], &mut [f32])>> =
                (0..shards).map(|_| Vec::with_capacity(nseg)).collect();
            for (aseg, cseg) in self
                .acc
                .segments
                .iter_mut()
                .zip(self.counts.segments.iter_mut())
            {
                let len = aseg.data.len();
                let mut arest: &mut [f32] = &mut aseg.data;
                let mut crest: &mut [f32] = &mut cseg.data;
                let mut start = 0usize;
                for (j, stripe) in stripes.iter_mut().enumerate() {
                    let end = stripe_end(len, shards, j);
                    let take = end - start;
                    let (ahead, atail) = std::mem::take(&mut arest).split_at_mut(take);
                    let (chead, ctail) = std::mem::take(&mut crest).split_at_mut(take);
                    stripe.push((start, ahead, chead));
                    arest = atail;
                    crest = ctail;
                    start = end;
                }
            }
            std::thread::scope(|s| {
                for stripe in stripes {
                    s.spawn(move || apply_stripe(spec, keys, updates, weight, stripe));
                }
            });
        }
        self.clients += 1;
        self.touched.record(keys);
        // the client uploaded the unscaled update; any discount is
        // server-side (same ledger as SparseAccumulator)
        self.up_bytes += updates.iter().map(|u| u.len() as u64 * 4).sum::<u64>()
            + keys.iter().map(|k| k.len() as u64 * 4).sum::<u64>();
        Ok(())
    }
}

/// End (exclusive) of stripe `j` when `len` coordinates split `shards`
/// ways: the first `len % shards` stripes get one extra coordinate.
fn stripe_end(len: usize, shards: usize, j: usize) -> usize {
    let base = len / shards;
    let extra = len % shards;
    (j + 1) * base + (j + 1).min(extra)
}

/// Apply one client's scatter restricted to a stripe: `stripe[seg]` is
/// `(start, acc, counts)` — the segment's coordinates `[start, start +
/// acc.len())`. Per coordinate this performs exactly the adds of
/// [`SelectSpec::deselect_add`], in the same order.
fn apply_stripe(
    spec: &SelectSpec,
    keys: &[Vec<u32>],
    updates: &[Vec<f32>],
    weight: f32,
    mut stripe: Vec<(usize, &mut [f32], &mut [f32])>,
) {
    for (b, upd) in spec.bindings.iter().zip(updates.iter()) {
        match b {
            Binding::Full { seg } => {
                let (start, acc, cnt) = &mut stripe[*seg];
                for (i, (d, c)) in acc.iter_mut().zip(cnt.iter_mut()).enumerate() {
                    let u = upd[*start + i];
                    *d += if weight == 1.0 { u } else { u * weight };
                    *c += 1.0;
                }
            }
            Binding::Keyed { seg, keyspace, map } => {
                let ks_keys = &keys[*keyspace];
                let m = ks_keys.len();
                let rl = map.row_len;
                let (start, acc, cnt) = &mut stripe[*seg];
                let (start, end) = (*start, *start + acc.len());
                for g in 0..map.groups {
                    for (j, &k) in ks_keys.iter().enumerate() {
                        let d = (g * map.keys_total + k as usize) * rl;
                        if d + rl <= start || d >= end {
                            continue;
                        }
                        let s = (g * m + j) * rl;
                        let lo = d.max(start);
                        let hi = (d + rl).min(end);
                        for idx in lo..hi {
                            let u = upd[s + (idx - d)];
                            acc[idx - start] += if weight == 1.0 { u } else { u * weight };
                            cnt[idx - start] += 1.0;
                        }
                    }
                }
            }
        }
    }
}

impl Aggregator for ShardedAccumulator {
    fn add_client(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
    ) -> Result<()> {
        self.add_scaled(spec, keys, updates, 1.0)
    }

    fn add_client_weighted(
        &mut self,
        spec: &SelectSpec,
        keys: &[Vec<u32>],
        updates: &[Vec<f32>],
        weight: f32,
    ) -> Result<()> {
        self.add_scaled(spec, keys, updates, weight)
    }

    fn finalize(self: Box<Self>, mode: AggMode) -> (ParamStore, TouchedKeys) {
        (
            finalize_mean(self.acc, &self.counts, self.clients, mode),
            self.touched,
        )
    }

    fn num_clients(&self) -> usize {
        self.clients
    }
}

pub(crate) fn finalize_mean(
    mut acc: ParamStore,
    counts: &ParamStore,
    clients: usize,
    mode: AggMode,
) -> ParamStore {
    match mode {
        AggMode::CohortMean => {
            let n = (clients.max(1)) as f32;
            for seg in &mut acc.segments {
                for v in &mut seg.data {
                    *v /= n;
                }
            }
        }
        AggMode::PerCoordMean => {
            for (seg, cseg) in acc.segments.iter_mut().zip(counts.segments.iter()) {
                for (v, &c) in seg.data.iter_mut().zip(cseg.data.iter()) {
                    if c > 0.0 {
                        *v /= c;
                    }
                }
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelArch;
    use crate::tensor::rng::Rng;

    fn setup() -> (ParamStore, SelectSpec) {
        let arch = ModelArch::logreg(8);
        let store = arch.init_store(&mut Rng::new(4, 0));
        (store.clone(), arch.select_spec())
    }

    #[test]
    fn cohort_mean_with_all_keys_equals_dense_fedavg() {
        let (store, spec) = setup();
        let all: Vec<u32> = (0..8).collect();
        let mut agg = Box::new(SparseAccumulator::new(&store));
        // two clients, updates = all ones and all twos
        for v in [1.0f32, 2.0] {
            let ups = vec![vec![v; 8 * 50], vec![v; 50]];
            agg.add_client(&spec, &[all.clone()], &ups).unwrap();
        }
        let (u, touched) = agg.finalize(AggMode::CohortMean);
        assert!(u.segments[0].data.iter().all(|&x| (x - 1.5).abs() < 1e-6));
        assert!(u.segments[1].data.iter().all(|&x| (x - 1.5).abs() < 1e-6));
        // finalize hands the trainer the merge set's touched rows directly
        assert_eq!(touched.count_in(0), 8);
    }

    #[test]
    fn cohort_vs_per_coord_on_disjoint_keys() {
        let (store, spec) = setup();
        let mut agg = Box::new(SparseAccumulator::new(&store));
        // client A selects row 0, client B selects row 1
        agg.add_client(&spec, &[vec![0]], &[vec![3.0; 50], vec![0.0; 50]])
            .unwrap();
        agg.add_client(&spec, &[vec![1]], &[vec![5.0; 50], vec![0.0; 50]])
            .unwrap();
        let (acc, counts) = agg.raw();
        assert_eq!(acc.segments[0].data[0], 3.0);
        assert_eq!(counts.segments[0].data[0], 1.0);
        let (u_cohort, _) = Box::new(SparseAccumulator {
            acc: acc.clone(),
            counts: counts.clone(),
            clients: 2,
            touched: TouchedKeys::default(),
            up_bytes: 0,
        })
        .finalize(AggMode::CohortMean);
        // cohort mean divides by N=2 even though each row was touched once
        assert_eq!(u_cohort.segments[0].data[0], 1.5);
        assert_eq!(u_cohort.segments[0].data[50], 2.5);
        let (u_coord, _) = Box::new(SparseAccumulator {
            acc: acc.clone(),
            counts: counts.clone(),
            clients: 2,
            touched: TouchedKeys::default(),
            up_bytes: 0,
        })
        .finalize(AggMode::PerCoordMean);
        assert_eq!(u_coord.segments[0].data[0], 3.0);
        assert_eq!(u_coord.segments[0].data[50], 5.0);
        // untouched rows stay zero under both
        assert_eq!(u_cohort.segments[0].data[100], 0.0);
        assert_eq!(u_coord.segments[0].data[100], 0.0);
    }

    #[test]
    fn weighted_add_scales_the_update_but_not_the_ledger() {
        let (store, spec) = setup();
        let mut plain = Box::new(SparseAccumulator::new(&store));
        let mut half = Box::new(SparseAccumulator::new(&store));
        let ups = vec![vec![2.0f32; 100], vec![2.0; 50]];
        let keys = vec![vec![0u32, 3]];
        plain.add_client(&spec, &keys, &ups).unwrap();
        half.add_client_weighted(&spec, &keys, &ups, 0.5).unwrap();
        assert_eq!(plain.up_bytes, half.up_bytes);
        let (pa, _) = plain.raw();
        let (ha, _) = half.raw();
        for (ps, hs) in pa.segments.iter().zip(ha.segments.iter()) {
            for (p, h) in ps.data.iter().zip(hs.data.iter()) {
                assert_eq!(*h, 0.5 * *p);
            }
        }
        // weight 1.0 routes through the exact unweighted path
        let mut a = Box::new(SparseAccumulator::new(&store));
        let mut b = Box::new(SparseAccumulator::new(&store));
        a.add_client(&spec, &keys, &ups).unwrap();
        b.add_client_weighted(&spec, &keys, &ups, 1.0).unwrap();
        for (sa, sb) in a.raw().0.segments.iter().zip(b.raw().0.segments.iter()) {
            for (x, y) in sa.data.iter().zip(sb.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn sharded_accumulator_is_bit_exact() {
        let (store, spec) = setup();
        // a small mixed workload: overlapping keys, a weighted add, a
        // dense-heavy update — enough to touch every scatter path
        let mut rng = Rng::new(77, 0);
        let cohort: Vec<(Vec<u32>, f32)> = (0..6)
            .map(|i| {
                let keys: Vec<u32> = rng
                    .sample_without_replacement(8, 3 + (i % 3))
                    .into_iter()
                    .map(|x| x as u32)
                    .collect();
                let w = if i % 2 == 0 { 1.0 } else { 0.25 + 0.1 * i as f32 };
                (keys, w)
            })
            .collect();
        let make_ups = |keys: &Vec<u32>, salt: f32| {
            vec![
                (0..keys.len() * 50)
                    .map(|j| salt + j as f32 * 0.01)
                    .collect::<Vec<f32>>(),
                (0..50).map(|j| -salt + j as f32 * 0.02).collect(),
            ]
        };
        for shards in [1usize, 2, 3, 8] {
            let mut seq = Box::new(SparseAccumulator::new(&store));
            let mut shd = Box::new(ShardedAccumulator::new(&store, shards));
            assert_eq!(shd.shards(), shards);
            for (i, (keys, w)) in cohort.iter().enumerate() {
                let ups = make_ups(keys, 0.5 + i as f32);
                seq.add_client_weighted(&spec, &[keys.clone()], &ups, *w)
                    .unwrap();
                shd.add_client_weighted(&spec, &[keys.clone()], &ups, *w)
                    .unwrap();
            }
            assert_eq!(seq.up_bytes, shd.up_bytes, "shards={shards}");
            assert_eq!(seq.num_clients(), shd.num_clients());
            assert_eq!(seq.touched(), shd.touched(), "touched union preserved");
            let (sa, sc) = seq.raw();
            let (ha, hc) = shd.raw();
            for (pair, label) in [((sa, ha), "acc"), ((sc, hc), "counts")] {
                for (x, y) in pair.0.segments.iter().zip(pair.1.segments.iter()) {
                    for (i, (a, b)) in x.data.iter().zip(y.data.iter()).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "shards={shards} {label} seg {} idx {i}",
                            x.name
                        );
                    }
                }
            }
            // finalize agrees bit-for-bit under both averaging modes
            let (u_seq, t_seq) = seq.finalize(AggMode::PerCoordMean);
            let (u_shd, t_shd) = shd.finalize(AggMode::PerCoordMean);
            assert_eq!(t_seq, t_shd);
            for (x, y) in u_seq.segments.iter().zip(u_shd.segments.iter()) {
                for (a, b) in x.data.iter().zip(y.data.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn sharded_accumulator_rejects_malformed_updates() {
        let (store, spec) = setup();
        let mut shd = ShardedAccumulator::new(&store, 4);
        // wrong tensor count
        assert!(shd.add_client(&spec, &[vec![0]], &[vec![0.0; 50]]).is_err());
        // keyed length mismatch
        assert!(shd
            .add_client(&spec, &[vec![0]], &[vec![0.0; 49], vec![0.0; 50]])
            .is_err());
        // dense length mismatch
        assert!(shd
            .add_client(&spec, &[vec![0]], &[vec![0.0; 50], vec![0.0; 49]])
            .is_err());
        assert_eq!(shd.num_clients(), 0, "failed adds absorb nothing");
    }

    #[test]
    fn touched_keys_report_the_union_of_absorbed_clients() {
        let (store, spec) = setup();
        let mut agg = Box::new(SparseAccumulator::new(&store));
        assert!(agg.touched().is_empty());
        agg.add_client(&spec, &[vec![0, 3]], &[vec![1.0; 100], vec![1.0; 50]])
            .unwrap();
        agg.add_client_weighted(&spec, &[vec![3, 5]], &[vec![1.0; 100], vec![1.0; 50]], 0.5)
            .unwrap();
        let t = agg.touched();
        assert_eq!(t.count(), 3);
        assert_eq!(t.count_in(0), 3);
        for k in [0u32, 3, 5] {
            assert!(t.contains(0, k));
        }
        assert!(!t.contains(0, 1), "unselected rows are untouched");
        assert!(!t.contains(7, 0), "unknown keyspace is empty");
        // deterministic ascending iteration per keyspace
        let seen: Vec<u32> = t.keyspaces().next().unwrap().iter().copied().collect();
        assert_eq!(seen, vec![0, 3, 5]);
    }

    #[test]
    fn touched_keys_merge_unions_keyspace_wise() {
        let mut a = TouchedKeys::new(1);
        a.record(&[vec![1, 3]]);
        let mut b = TouchedKeys::new(2);
        b.record(&[vec![3, 5], vec![0]]);
        a.merge(&b);
        assert_eq!(a.count_in(0), 3);
        assert_eq!(a.count_in(1), 1);
        for k in [1u32, 3, 5] {
            assert!(a.contains(0, k));
        }
        assert!(a.contains(1, 0));
    }

    #[test]
    fn up_bytes_track_slice_plus_keys() {
        let (store, spec) = setup();
        let mut agg = Box::new(SparseAccumulator::new(&store));
        agg.add_client(&spec, &[vec![0, 3]], &[vec![0.0; 100], vec![0.0; 50]])
            .unwrap();
        assert_eq!(agg.up_bytes, (150 * 4 + 2 * 4) as u64);
    }
}

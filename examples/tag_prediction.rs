//! Domain scenario (paper §2.3 / §5.2): Stack-Overflow-style tag prediction
//! with *structured* select keys, sweeping the client key budget m and
//! comparing the three FedSelect system implementations (§3.2) on identical
//! training trajectories.
//!
//! ```text
//! cargo run --release --example tag_prediction [-- --quick]
//! ```

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::{build_dataset, Trainer};
use fedselect::data::bow::BowConfig;
use fedselect::error::Result;
use fedselect::fedselect::{KeyPolicy, SliceImpl};
use fedselect::metrics::{human_bytes, Table};

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let vocab = 4096;
    let ms: &[usize] = if quick { &[128, 4096] } else { &[64, 256, 1024, 4096] };
    let rounds = if quick { 5 } else { 20 };

    let ds_cfg = BowConfig::new(vocab, 50).with_clients(if quick { 40 } else { 200 }, 10, 30);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg.clone()));

    // -- sweep m with structured keys ------------------------------------
    let mut t = Table::new(
        "Tag prediction: key budget sweep (Top-m structured keys)",
        &["m", "rel_size", "recall@5", "down/round/client"],
    );
    for &m in ms {
        let mut cfg = TrainConfig::logreg_default(vocab, m);
        cfg.dataset = DatasetConfig::Bow(ds_cfg.clone());
        cfg.rounds = rounds;
        cfg.cohort = 25;
        cfg.eval.every = 0;
        let mut tr = Trainer::with_dataset(cfg, dataset.clone())?;
        let rel = tr.rel_model_size();
        let rep = tr.run()?;
        let per_client =
            rep.total_down_bytes / (rep.rounds.len() as u64 * 25);
        t.push(vec![
            m.to_string(),
            format!("{rel:.3}"),
            format!("{:.3}", rep.final_eval.metric),
            human_bytes(per_client),
        ]);
    }
    println!("{}", t.to_pretty());

    // -- compare the three system implementations at fixed m -------------
    let m = ms[0];
    let mut t2 = Table::new(
        "System implementations at fixed m (identical numerics)",
        &["impl", "recall@5", "down_total", "up_keys", "psi_evals", "pregen", "memo_hits"],
    );
    let mut finals = Vec::new();
    for imp in [SliceImpl::Broadcast, SliceImpl::OnDemand, SliceImpl::PregenCdn] {
        let mut cfg = TrainConfig::logreg_default(vocab, m);
        cfg.dataset = DatasetConfig::Bow(ds_cfg.clone());
        cfg.policies = vec![KeyPolicy::TopFreq { m }];
        cfg.rounds = rounds.min(8);
        cfg.cohort = 25;
        cfg.slice_impl = imp;
        cfg.eval.every = 0;
        let mut tr = Trainer::with_dataset(cfg, dataset.clone())?;
        let rep = tr.run()?;
        let comm = rep.rounds.iter().fold(
            fedselect::fedselect::RoundComm::default(),
            |mut acc, r| {
                acc.accumulate(&r.comm);
                acc
            },
        );
        finals.push(rep.final_eval.metric);
        t2.push(vec![
            format!("{imp:?}"),
            format!("{:.3}", rep.final_eval.metric),
            human_bytes(comm.down_bytes),
            human_bytes(comm.up_key_bytes),
            comm.psi_evals.to_string(),
            comm.pregen_slices.to_string(),
            comm.memo_hits.to_string(),
        ]);
    }
    println!("{}", t2.to_pretty());
    // same seeds + same slices => identical final metric across impls
    for w in finals.windows(2) {
        assert!(
            (w[0] - w[1]).abs() < 1e-9,
            "slice services must be numerically interchangeable"
        );
    }
    println!("all three implementations produced identical training trajectories ✔");
    Ok(())
}

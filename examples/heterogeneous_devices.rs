//! Device-heterogeneity scenario (paper §3): FedSelect lets different
//! clients receive different-*sized* sub-models in the same round — high-end
//! phones take a large key budget, low-end phones a small one — something
//! plain BROADCAST fundamentally cannot do.
//!
//! Since the cohort-scheduler subsystem landed, this is first-class: the
//! `tiered-3` fleet assigns every client a real [`DeviceProfile`] (downlink
//! and uplink bandwidth, compute throughput, a memory cap, a failure
//! hazard), the `memory-capped` policy clamps each selected client's select
//! budget `m_i` to what its device can hold, and the `SimClock` reports
//! straggler-bound simulated round wall-time instead of a hand-rolled
//! dropout coin. Compare with the pre-scheduler revision of this file,
//! which drove the slice service and aggregation by hand.
//!
//! ```text
//! cargo run --release --example heterogeneous_devices
//! ```

use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::AggregationMode;
use fedselect::data::bow::BowConfig;
use fedselect::error::Result;
use fedselect::fedselect::KeyPolicy;
use fedselect::metrics::{fleet_summary, human_bytes};
use fedselect::prelude::Trainer;
use fedselect::scheduler::{FleetKind, SchedPolicy};

const VOCAB: usize = 2048;
const M: usize = 1024; // high-end budget; lower tiers are clamped from it
const ROUNDS: usize = 12;

fn main() -> Result<()> {
    let mut cfg = TrainConfig::logreg_default(VOCAB, M);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(VOCAB, 50).with_clients(120, 0, 30));
    cfg.rounds = ROUNDS;
    cfg.cohort = 18;
    cfg.fleet = FleetKind::Tiered3;
    cfg.sched_policy = SchedPolicy::MemoryCapped;
    cfg.mem_cap_frac = 0.1; // low-end holds 10% of the server model
    cfg.policies = vec![KeyPolicy::TopFreq { m: M }];
    cfg.eval.every = 0;
    cfg.eval.max_examples = 1500;
    cfg.seed = 42;
    let buffered_cfg = cfg.clone();

    let mut trainer = Trainer::new(cfg)?;
    {
        let fleet = trainer.scheduler().fleet();
        println!(
            "fleet {}: {} clients in {} tiers {:?}",
            fleet.kind,
            fleet.len(),
            fleet.num_tiers(),
            (0..fleet.num_tiers())
                .map(|t| fleet.tier_name(t))
                .collect::<Vec<_>>()
        );
    }
    let report = trainer.run()?;

    for rec in report.rounds.iter().filter(|r| r.round % 4 == 0) {
        println!(
            "round {:>2}: sim {:>6.2}s | per-tier completed {:?} dropped {:?}",
            rec.round, rec.sim_round_s, rec.tier_completed, rec.tier_dropped
        );
    }
    println!(
        "\nglobal model after {ROUNDS} rounds: recall@5 {:.3}, loss {:.3} \
         | sim training time {:.1}s | down {}",
        report.final_eval.metric,
        report.final_eval.loss,
        report.total_sim_s,
        human_bytes(report.total_down_bytes),
    );

    let fleet = trainer.scheduler().fleet();
    println!("{}", fleet_summary(fleet, &report.rounds).to_pretty());

    // low-end devices must have downloaded less *per client served* than
    // high-end ones: that asymmetry is the whole point of FedSelect
    let served = |t: usize| -> u64 {
        report
            .rounds
            .iter()
            .map(|r| (r.tier_completed[t] + r.tier_dropped[t]) as u64)
            .sum()
    };
    let down = |t: usize| -> u64 {
        report.rounds.iter().map(|r| r.tier_down_bytes[t]).sum()
    };
    let per_client = |t: usize| down(t) as f64 / served(t).max(1) as f64;
    println!(
        "per-served-client download: low-end {} vs high-end {}",
        human_bytes(per_client(0) as u64),
        human_bytes(per_client(2) as u64),
    );
    assert!(
        per_client(0) < per_client(2),
        "low-end must download less per client"
    );

    // The same fleet through the event-driven round engine: buffered
    // (FedBuff-style) aggregation closes each round at a goal count instead
    // of the slowest low-end phone, so the same training run finishes in
    // strictly less simulated time — same seed, same cohorts, same
    // per-client timings; only the close rule differs.
    let mut cfg = buffered_cfg;
    cfg.agg_mode = AggregationMode::Buffered {
        goal_count: 14, // of the 18-client cohort
        max_staleness: 4,
    };
    let mut buffered = Trainer::new(cfg)?;
    let breport = buffered.run()?;
    println!(
        "\nbuffered engine ({}): sim training time {:.1}s vs sync {:.1}s \
         | recall@5 {:.3} vs {:.3} | discarded {}",
        breport.rounds[0].mode,
        breport.total_sim_s,
        report.total_sim_s,
        breport.final_eval.metric,
        report.final_eval.metric,
        breport.total_discarded,
    );
    assert!(
        breport.total_sim_s < report.total_sim_s,
        "goal-count close must beat the straggler barrier"
    );
    Ok(())
}

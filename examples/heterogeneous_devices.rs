//! Device-heterogeneity scenario (paper §3): FedSelect lets different
//! clients receive different-*sized* sub-models in the same round — high-end
//! phones take a large key budget, low-end phones a small one — something
//! plain BROADCAST fundamentally cannot do.
//!
//! This example partitions the client population into three device tiers,
//! assigns each tier its own key budget, runs federated training rounds
//! manually against the library primitives (slice service + deselect
//! aggregation + server optimizer), and reports per-tier download/memory
//! alongside model quality. It also injects client dropout (§6).
//!
//! ```text
//! cargo run --release --example heterogeneous_devices
//! ```

use fedselect::aggregation::{AggMode, Aggregator, SparseAccumulator};
use fedselect::clients::{build_cu_batch, build_eval_batches, client_memory_bytes, Engine};
use fedselect::coordinator::build_dataset;
use fedselect::config::DatasetConfig;
use fedselect::data::bow::BowConfig;
use fedselect::error::Result;
use fedselect::fedselect::{ClientKeys, KeyPolicy, RoundSession, SliceImpl, SliceService};
use fedselect::metrics::{human_bytes, Table};
use fedselect::model::ModelArch;
use fedselect::optim::{Optimizer, ServerOpt};
use fedselect::tensor::rng::Rng;

/// m per device tier — must match AOT client-update variants.
const TIERS: [(&str, usize); 3] = [("low-end", 64), ("mid", 256), ("high-end", 1024)];
const VOCAB: usize = 2048;
const ROUNDS: usize = 12;
const PER_TIER: usize = 6; // clients per tier per round
const DROPOUT: f32 = 0.15;

fn main() -> Result<()> {
    let arch = ModelArch::logreg(VOCAB);
    let ds_cfg = BowConfig::new(VOCAB, 50).with_clients(120, 0, 30);
    let dataset = build_dataset(&DatasetConfig::Bow(ds_cfg));
    let mut rng = Rng::new(42, 9);
    let mut store = arch.init_store(&mut rng);
    let spec = arch.select_spec();
    let mut service = SliceImpl::PregenCdn.build();
    let mut engine = Engine::Native;
    let mut opt = Optimizer::new(ServerOpt::fedadagrad(0.1), &store);

    let mut tier_down = [0u64; 3];
    let mut tier_mem = [0usize; 3];
    let mut dropped_total = 0usize;

    for round in 0..ROUNDS {
        let mut agg = SparseAccumulator::new(&store);
        let cohort = dataset.sample_cohort(&mut rng, PER_TIER * TIERS.len());

        // per-tier key budgets drawn up front: FedSelect serves
        // different-*sized* sub-models from the same round session
        let mut cohort_keys: Vec<ClientKeys> = Vec::with_capacity(cohort.len());
        let mut cohort_rngs = Vec::with_capacity(cohort.len());
        for (slot, &ci) in cohort.iter().enumerate() {
            let (_, m) = TIERS[slot % TIERS.len()];
            let client = &dataset.train[ci];
            let mut crng = rng.fork(client.id ^ round as u64);
            cohort_keys.push(vec![KeyPolicy::TopFreq { m }.keys_for(
                client,
                VOCAB,
                &mut crng,
                None,
                false,
            )]);
            cohort_rngs.push(crng);
        }

        // one immutable session slices the whole heterogeneous cohort,
        // 4 threads at a time
        let session = service.begin_round(&store, &spec)?;
        let bundles = session.fetch_batch(&cohort_keys, 4)?;

        for (slot, (&ci, bundle)) in cohort.iter().zip(bundles.into_iter()).enumerate() {
            let tier = slot % TIERS.len();
            let (_, m) = TIERS[tier];
            let client = &dataset.train[ci];
            let crng = &mut cohort_rngs[slot];
            let keys = &cohort_keys[slot];
            tier_down[tier] += bundle.bytes();
            if crng.f32() < DROPOUT {
                dropped_total += 1;
                continue; // downloaded, then dropped (§6 failure pattern)
            }
            let (batch, _) = build_cu_batch(&arch, client, keys, crng)?;
            tier_mem[tier] =
                tier_mem[tier].max(client_memory_bytes(bundle.total_floats(), &batch));
            let deltas = engine.client_update(&arch, &[m], bundle.into_vecs(), &batch, 0.5)?;
            agg.add_client(&spec, keys, &deltas)?;
        }
        let _ = session.finish();
        let n = agg.num_clients();
        if n > 0 {
            let update = Box::new(agg).finalize(AggMode::CohortMean);
            opt.step(&mut store, &update);
        }
        if (round + 1) % 4 == 0 {
            println!("round {:>2}: completed cohort with dropouts so far = {dropped_total}", round + 1);
        }
    }

    // evaluate the single global model all tiers co-trained
    let pool: Vec<&fedselect::data::Example> = dataset
        .test
        .iter()
        .flat_map(|c| c.examples.iter())
        .take(1500)
        .collect();
    let (mut loss, mut rec, mut w) = (0.0, 0.0, 0.0);
    for b in build_eval_batches(&arch, &pool)? {
        let (l, r, ws) = engine.eval(&arch, &store, &b)?;
        loss += l;
        rec += r;
        w += ws;
    }
    println!(
        "\nglobal model after {ROUNDS} rounds: recall@5 {:.3}, loss {:.3} ({} eval examples)",
        rec / w,
        loss / w,
        w as usize
    );

    let mut t = Table::new(
        "Per-tier cost (one global model, heterogeneous slices)",
        &["tier", "m", "rel_size", "download_total", "peak_client_mem"],
    );
    let server_floats = spec.server_floats(&store) as f64;
    for (i, (name, m)) in TIERS.iter().enumerate() {
        let rel = spec.client_floats(&store, &[*m]) as f64 / server_floats;
        t.push(vec![
            name.to_string(),
            m.to_string(),
            format!("{rel:.3}"),
            human_bytes(tier_down[i]),
            human_bytes(tier_mem[i] as u64),
        ]);
    }
    println!("{}", t.to_pretty());
    assert!(tier_down[0] < tier_down[2], "low-end must download less");
    println!("dropped clients (post-download): {dropped_total}");
    Ok(())
}

//! End-to-end driver: train a transformer whose **server** model is far
//! larger than any client could hold, proving all three layers compose —
//! Rust coordinator -> FEDSELECT slicing -> AOT-compiled XLA client updates
//! (Pallas gather/scatter + tiled-matmul kernels inside) -> sparse deselect
//! aggregation -> FedAdam server updates.
//!
//! Server model: 65,536-token vocabulary, d=256, 4 layers (≈40M params).
//! Client slice: 1,024 vocab rows + 256 FFN neurons (≈2.5% of the server
//! model). This is the paper's headline capability: the server trains a
//! model clients could not download, store, or update in full.
//!
//! Requires artifacts: `make artifacts` (e2e_cu / e2e_eval variants).
//!
//! ```text
//! cargo run --release --example e2e_transformer -- [--rounds 200] [--cohort 8]
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use fedselect::config::{DatasetConfig, EngineKind, TrainConfig};
use fedselect::coordinator::Trainer;
use fedselect::data::text::TextConfig;
use fedselect::error::Result;
use fedselect::fedselect::KeyPolicy;
use fedselect::metrics::human_bytes;
use fedselect::model::ModelArch;
use fedselect::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).unwrap();
    let rounds: usize = args.parse_or("rounds", 200).unwrap();
    let cohort: usize = args.parse_or("cohort", 8).unwrap();
    let eval_every: usize = args.parse_or("eval-every", 10).unwrap();
    let artifacts = args.str_or("artifacts-dir", "artifacts");
    // --arch large: the 65k-vocab / 40M-param server model (e2e_cu artifact).
    // XLA-compiling its training graph takes many minutes on a single CPU
    // core, so the default is the 2048-vocab arch — the same code path and
    // the same server≫client property, at a compile cost CI can afford.
    let large = args.str_or("arch", "small") == "large";

    let (arch, mv, dh) = if large {
        (ModelArch::transformer_e2e(), 1024usize, 256usize)
    } else {
        (ModelArch::transformer(), 256usize, 64usize)
    };
    let (vocab, seq) = match &arch {
        ModelArch::Transformer { shape, .. } => (shape.vocab, shape.seq),
        _ => unreachable!(),
    };

    let mut cfg = TrainConfig::transformer_default(mv, dh);
    cfg.arch = arch;
    cfg.dataset = DatasetConfig::Text(
        TextConfig::new(vocab, seq).with_clients(400, 0, 60),
    );
    cfg.policies = vec![
        KeyPolicy::TopFreq { m: mv },
        KeyPolicy::RandomGlobal { m: dh },
    ];
    cfg.rounds = rounds;
    cfg.cohort = cohort;
    cfg.engine = EngineKind::Pjrt {
        artifacts_dir: artifacts,
    };
    cfg.eval.every = eval_every;
    cfg.eval.max_examples = 256;
    cfg.server_opt = fedselect::optim::ServerOpt::fedadam(0.05);
    cfg.client_lr = 0.2;

    let mut tr = Trainer::new(cfg)?;
    let server_bytes = tr.store().bytes();
    println!(
        "server model: {} params ({}) | client slice: {:.2}% of server",
        tr.store().num_params(),
        human_bytes(server_bytes as u64),
        tr.rel_model_size() * 100.0
    );
    println!("rounds={rounds} cohort={cohort} | loss curve:");

    let t0 = std::time::Instant::now();
    let mut loss_curve: Vec<(usize, f64, f64)> = Vec::new();
    for r in 0..rounds {
        let rec = tr.run_round()?;
        if (r + 1) % eval_every == 0 || r + 1 == rounds {
            let e = tr.evaluate()?;
            loss_curve.push((e.round, e.loss, e.metric));
            println!(
                "round {:>4}: loss {:.4}  token-acc {:.4}  (round wall {:.0} ms, down {}/client)",
                e.round,
                e.loss,
                e.metric,
                rec.wall_ms,
                human_bytes(rec.comm.down_bytes / cohort as u64)
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // write the loss curve for EXPERIMENTS.md
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("round,loss,token_accuracy\n");
    for (r, l, m) in &loss_curve {
        csv.push_str(&format!("{r},{l:.5},{m:.5}\n"));
    }
    std::fs::write("results/e2e_transformer_loss.csv", csv)?;

    let first = loss_curve.first().unwrap();
    let last = loss_curve.last().unwrap();
    println!(
        "\nloss {:.4} -> {:.4} over {rounds} rounds ({:.1} min wall); curve in results/e2e_transformer_loss.csv",
        first.1,
        last.1,
        wall / 60.0
    );
    assert!(
        last.1 < first.1,
        "training must reduce loss ({} -> {})",
        first.1,
        last.1
    );
    Ok(())
}

//! Quickstart: train a sparse logistic-regression tag predictor with
//! FedSelect and compare the communication ledger against the full-broadcast
//! baseline — the paper's headline claim in ~60 lines.
//!
//! Runs artifact-free on the native engine:
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use fedselect::baselines::full_broadcast;
use fedselect::config::{DatasetConfig, TrainConfig};
use fedselect::coordinator::Trainer;
use fedselect::data::bow::BowConfig;
use fedselect::error::Result;
use fedselect::metrics::human_bytes;

fn main() -> Result<()> {
    let vocab = 2048;
    let m = 256; // each client selects its 256 most frequent words

    let mut cfg = TrainConfig::logreg_default(vocab, m);
    cfg.dataset = DatasetConfig::Bow(BowConfig::new(vocab, 50).with_clients(120, 12, 24));
    cfg.rounds = 15;
    cfg.cohort = 25;
    cfg.eval.every = 5;

    println!("--- FedSelect (m = {m} of n = {vocab}) ---");
    let mut tr = Trainer::new(cfg.clone())?;
    println!(
        "server model: {} params; client slice ratio {:.3}",
        tr.store().num_params(),
        tr.rel_model_size()
    );
    let fs = tr.run()?;
    for e in &fs.evals {
        println!("  round {:>3}: recall@5 {:.3}  loss {:.3}", e.round, e.metric, e.loss);
    }

    println!("--- Baseline: full broadcast (no selection) ---");
    let mut base = Trainer::new(full_broadcast(cfg))?;
    let bl = base.run()?;
    println!(
        "  final recall@5 {:.3} (fedselect {:.3})",
        bl.final_eval.metric, fs.final_eval.metric
    );

    let saving = bl.total_down_bytes as f64 / fs.total_down_bytes.max(1) as f64;
    println!("--- Communication ---");
    println!(
        "  download: fedselect {} vs broadcast {}  ({saving:.1}x reduction)",
        human_bytes(fs.total_down_bytes),
        human_bytes(bl.total_down_bytes)
    );
    println!(
        "  upload:   fedselect {} vs broadcast {}",
        human_bytes(fs.total_up_bytes),
        human_bytes(bl.total_up_bytes)
    );
    assert!(saving > 2.0, "fedselect should save download bytes");
    Ok(())
}

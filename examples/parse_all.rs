//! Artifact sanity: parse every manifest entry's HLO text through the same
//! XLA text parser the runtime uses (`HloModuleProto::from_text_file`).
//! Catches jax-emitted instructions the pinned xla_extension 0.5.1 cannot
//! parse (e.g. `topk(..., largest=true)`) without paying full compilation.
fn main() {
    let rt = fedselect::runtime::PjrtRuntime::load("artifacts").unwrap();
    let names: Vec<String> = rt.manifest().names().iter().map(|s| s.to_string()).collect();
    for name in names {
        let art = rt.artifact(&name).unwrap().clone();
        let path = format!("artifacts/{}", art.path);
        match xla::HloModuleProto::from_text_file(path.as_str()) {
            Ok(_) => println!("OK   {name}"),
            Err(e) => println!("FAIL {name}: {}", e.to_string().lines().next().unwrap_or("")),
        }
    }
}

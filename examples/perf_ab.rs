//! §Perf A/B: zero-fill+copy vs sequential-append slice materialization.
use fedselect::model::{KeyMap, ModelArch};
use fedselect::tensor::rng::Rng;
use std::time::Instant;

fn slice_zerofill(src: &[f32], map: &KeyMap, keys: &[u32]) -> Vec<f32> {
    let m = keys.len();
    let rl = map.row_len;
    let mut out = vec![0.0f32; map.sliced_len(m)];
    for g in 0..map.groups {
        for (j, &k) in keys.iter().enumerate() {
            let s = (g * map.keys_total + k as usize) * rl;
            let d = (g * m + j) * rl;
            out[d..d + rl].copy_from_slice(&src[s..s + rl]);
        }
    }
    out
}

fn main() {
    let arch = ModelArch::logreg(8192);
    let store = arch.init_store(&mut Rng::new(1, 0));
    let spec = arch.select_spec();
    let map = KeyMap::rows(8192, 50);
    let keys: Vec<u32> = Rng::new(3, 1).sample_without_replacement(8192, 1024)
        .into_iter().map(|x| x as u32).collect();
    let src = &store.segments[0].data;
    let iters = 2000;
    // warmup + old
    for _ in 0..50 { std::hint::black_box(slice_zerofill(src, &map, &keys)); }
    let t0 = Instant::now();
    for _ in 0..iters { std::hint::black_box(slice_zerofill(src, &map, &keys)); }
    let old = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
    // new (library path)
    let kk = vec![keys.clone()];
    for _ in 0..50 { std::hint::black_box(spec.slice(&store, &kk).unwrap()); }
    let t1 = Instant::now();
    for _ in 0..iters { std::hint::black_box(spec.slice(&store, &kk).unwrap()); }
    let new = t1.elapsed().as_secs_f64() * 1e6 / iters as f64;
    println!("slice m=1024 of K=8192 (50 f32/row): zerofill {:.1}us -> append {:.1}us ({:.1}% faster)",
             old, new, (old - new) / old * 100.0);
}

"""L2 correctness: model client-update / eval semantics.

These properties are what the Rust coordinator relies on: the model-delta
convention (delta = initial - final), padding-weight neutrality, shape
stability, and actual learning progress on a synthetic task.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

KEY = jax.random.PRNGKey(0)


def _logreg_batch(key, s, mb, m, t):
    kx, ky = jax.random.split(key)
    x = (jax.random.uniform(kx, (s, mb, m)) < 0.15).astype(jnp.float32)
    y = (jax.random.uniform(ky, (s, mb, t)) < 0.2).astype(jnp.float32)
    return x, y, jnp.ones((s, mb), jnp.float32)


class TestLogreg:
    def test_zero_lr_zero_delta(self):
        w, b = M.logreg_init(KEY, 32, 8)
        x, y, wgt = _logreg_batch(KEY, 2, 4, 32, 8)
        dw, db = M.logreg_client_update(w, b, x, y, wgt, 0.0)
        assert float(jnp.abs(dw).max()) == 0.0
        assert float(jnp.abs(db).max()) == 0.0

    def test_delta_is_initial_minus_final(self):
        """delta must equal lr * sum of per-step gradients along the SGD path."""
        w, b = M.logreg_init(KEY, 16, 4)
        x, y, wgt = _logreg_batch(KEY, 3, 4, 16, 4)
        lr = 0.1
        dw, db = M.logreg_client_update(w, b, x, y, wgt, lr)
        # replay the epoch manually
        wc, bc = w, b
        for i in range(3):
            g = jax.grad(M._logreg_loss)((wc, bc), x[i], y[i], wgt[i])
            wc = wc - lr * g[0]
            bc = bc - lr * g[1]
        np.testing.assert_allclose(dw, w - wc, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(db, b - bc, rtol=1e-5, atol=1e-6)

    def test_padding_rows_are_neutral(self):
        w, b = M.logreg_init(KEY, 16, 4)
        x, y, wgt = _logreg_batch(KEY, 2, 4, 16, 4)
        d1 = M.logreg_client_update(w, b, x, y, wgt, 0.1)
        # corrupt padded rows wildly; with weight 0 they must not matter
        wgt2 = wgt.at[:, -1].set(0.0)
        d_ref = M.logreg_client_update(w, b, x, y, wgt2, 0.1)
        x2 = x.at[:, -1].set(137.0)
        y2 = y.at[:, -1].set(1.0)
        d_pad = M.logreg_client_update(w, b, x2, y2, wgt2, 0.1)
        np.testing.assert_allclose(d_ref[0], d_pad[0], rtol=1e-5, atol=1e-6)
        # sanity: weights actually matter when nonzero
        assert float(jnp.abs(d1[0] - d_ref[0]).max()) > 0

    def test_eval_recall_at_5_perfect_model(self):
        # logits exactly equal to labels -> all true tags are in top-5 when
        # each example has <= 5 tags.
        t = 12
        w = jnp.zeros((6, t))
        b = jnp.zeros((t,))
        x = jnp.zeros((4, 6))
        y = jnp.zeros((4, t)).at[:, :3].set(1.0)
        b = b.at[:3].set(10.0)
        loss, rec5, ws = M.logreg_eval(w, b, x, y, jnp.ones(4))
        assert float(rec5) / float(ws) == pytest.approx(1.0)

    def test_eval_zero_weight_examples_excluded(self):
        w, b = M.logreg_init(KEY, 16, 6)
        x, y, _ = _logreg_batch(KEY, 1, 8, 16, 6)
        x, y = x[0], y[0]
        wgt = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
        l1, r1, s1 = M.logreg_eval(w, b, x, y, wgt)
        x2 = x.at[4:].set(99.0)
        l2, r2, s2 = M.logreg_eval(w, b, x2, y, wgt)
        assert float(l1) == pytest.approx(float(l2), rel=1e-6)
        assert float(r1) == pytest.approx(float(r2), rel=1e-6)
        assert float(s1) == 4.0


class TestMlp:
    def test_shapes_and_zero_lr(self):
        p = M.mlp2nn_init(KEY, 20, 64, 10)
        x = jax.random.normal(KEY, (2, 4, 784))
        y = jax.random.randint(KEY, (2, 4), 0, 10)
        wgt = jnp.ones((2, 4))
        d = M.mlp2nn_client_update(*p, x, y, wgt, 0.0)
        assert len(d) == 6
        for dp, pp in zip(d, p):
            assert dp.shape == pp.shape
            assert float(jnp.abs(dp).max()) == 0.0

    def test_learning_reduces_loss(self):
        p = M.mlp2nn_init(KEY, 50, 64, 5)
        x = jax.random.normal(KEY, (4, 8, 784))
        y = jax.random.randint(KEY, (4, 8), 0, 5)
        wgt = jnp.ones((4, 8))
        loss0 = M._mlp_loss(p, x.reshape(-1, 784), y.reshape(-1), wgt.reshape(-1))
        d = M.mlp2nn_client_update(*p, x, y, wgt, 0.05)
        p1 = tuple(pp - dd for pp, dd in zip(p, d))  # final = initial - delta
        loss1 = M._mlp_loss(p1, x.reshape(-1, 784), y.reshape(-1), wgt.reshape(-1))
        assert float(loss1) < float(loss0)

    def test_eval_counts(self):
        p = M.mlp2nn_init(KEY, 20, 32, 4)
        x = jax.random.normal(KEY, (16, 784))
        y = jax.random.randint(KEY, (16,), 0, 4)
        wgt = jnp.ones((16,))
        loss, correct, ws = M.mlp2nn_eval(*p, x, y, wgt)
        assert 0.0 <= float(correct) <= 16.0
        assert float(ws) == 16.0


class TestCnn:
    def test_update_shapes(self):
        p = M.cnn_init(KEY, 8, 10)
        x = jax.random.normal(KEY, (2, 3, 28, 28, 1))
        y = jax.random.randint(KEY, (2, 3), 0, 10)
        wgt = jnp.ones((2, 3))
        d = M.cnn_client_update(*p, x, y, wgt, 0.01)
        assert len(d) == 8
        for dp, pp in zip(d, p):
            assert dp.shape == pp.shape

    def test_learning_reduces_loss(self):
        p = M.cnn_init(KEY, 8, 4)
        kx, ky = jax.random.split(KEY)
        x = jax.random.normal(kx, (3, 6, 28, 28, 1))
        y = jax.random.randint(ky, (3, 6), 0, 4)
        wgt = jnp.ones((3, 6))
        flat = (x.reshape(-1, 28, 28, 1), y.reshape(-1), wgt.reshape(-1))
        loss0 = M._cnn_loss(p, *flat)
        d = M.cnn_client_update(*p, x, y, wgt, 0.05)
        p1 = tuple(pp - dd for pp, dd in zip(p, d))
        loss1 = M._cnn_loss(p1, *flat)
        assert float(loss1) < float(loss0)


class TestTransformer:
    CFG = M.TransformerCfg(mv=64, d=32, seq=8, layers=1, heads=2, dh=48)

    def _batch(self, s=2, mb=3):
        kx, ky = jax.random.split(KEY)
        x = jax.random.randint(kx, (s, mb, self.CFG.seq), 0, self.CFG.mv)
        y = jax.random.randint(ky, (s, mb, self.CFG.seq), 0, self.CFG.mv)
        return x, y, jnp.ones((s, mb, self.CFG.seq), jnp.float32)

    def test_param_bookkeeping(self):
        names = self.CFG.param_names()
        shapes = self.CFG.param_shapes()
        assert len(names) == len(shapes) == 2 + 12 * self.CFG.layers + 4
        p = M.transformer_init(KEY, self.CFG)
        assert tuple(pp.shape for pp in p) == tuple(shapes)

    def test_update_shapes_and_zero_lr(self):
        p = M.transformer_init(KEY, self.CFG)
        x, y, wgt = self._batch()
        cu = M.make_transformer_client_update(self.CFG)
        d = cu(*p, x, y, wgt, 0.0)
        assert len(d) == len(p)
        assert all(float(jnp.abs(dd).max()) == 0.0 for dd in d)

    def test_learning_reduces_loss(self):
        p = M.transformer_init(KEY, self.CFG)
        x, y, wgt = self._batch(s=4, mb=4)
        loss_fn = M.make_transformer_loss(self.CFG)
        cu = M.make_transformer_client_update(self.CFG)
        loss0 = loss_fn(p, x[0], y[0], wgt[0])
        d = cu(*p, x, y, wgt, 0.1)
        p1 = tuple(pp - dd for pp, dd in zip(p, d))
        loss1 = loss_fn(p1, x[0], y[0], wgt[0])
        assert float(loss1) < float(loss0)

    def test_causality(self):
        """Changing a future token must not change past logits."""
        p = M.transformer_init(KEY, self.CFG)
        x = jax.random.randint(KEY, (1, self.CFG.seq), 0, self.CFG.mv)
        logits = M._transformer_logits(p, x, self.CFG)
        x2 = x.at[0, -1].set((int(x[0, -1]) + 1) % self.CFG.mv)
        logits2 = M._transformer_logits(p, x2, self.CFG)
        np.testing.assert_allclose(
            logits[0, :-1], logits2[0, :-1], rtol=1e-5, atol=1e-5
        )
        assert float(jnp.abs(logits[0, -1] - logits2[0, -1]).max()) > 1e-6

    def test_eval_token_weighting(self):
        p = M.transformer_init(KEY, self.CFG)
        ev = M.make_transformer_eval(self.CFG)
        x, y, wgt = self._batch(s=1, mb=2)
        x, y, wgt = x[0], y[0], wgt[0]
        loss_all, _, n_all = ev(*p, x, y, wgt)
        wgt0 = wgt.at[1].set(0.0)
        loss_half, _, n_half = ev(*p, x, y, wgt0)
        assert float(n_all) == 2 * self.CFG.seq
        assert float(n_half) == self.CFG.seq
        assert float(loss_half) < float(loss_all)

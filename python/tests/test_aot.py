"""AOT pipeline: manifest integrity and numeric round-trip through HLO.

The Rust runtime trusts manifest.json blindly (argument order, shapes,
dtypes), so these tests pin that contract: files exist, hashes match, and —
crucially — executing the lowered HLO text through the XLA client gives the
same numbers as calling the jitted L2 function directly.
"""

import hashlib
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def quick_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    return out


def _manifest(d):
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)


def test_manifest_files_and_hashes(quick_dir):
    man = _manifest(quick_dir)
    assert man["version"] == 1
    assert len(man["artifacts"]) >= 5
    for a in man["artifacts"]:
        p = os.path.join(quick_dir, a["path"])
        assert os.path.exists(p), a["name"]
        text = open(p).read()
        assert hashlib.sha256(text.encode()).hexdigest() == a["hlo_sha256"]
        assert text.startswith("HloModule")
        assert a["kind"] in ("client_update", "eval")
        for io in a["inputs"] + a["outputs"]:
            assert io["dtype"] in ("f32", "i32")


def test_manifest_shapes_consistent(quick_dir):
    man = _manifest(quick_dir)
    by_name = {a["name"]: a for a in man["artifacts"]}
    lr = by_name["logreg_cu_m64"]
    m, t = lr["meta"]["m"], lr["meta"]["t"]
    ins = {i["name"]: i["shape"] for i in lr["inputs"]}
    assert ins["w"] == [m, t]
    assert ins["lr"] == []
    outs = {o["name"]: o["shape"] for o in lr["outputs"]}
    assert outs["dw"] == [m, t]
    assert outs["db"] == [t]


def test_hlo_text_parses_back(quick_dir):
    """The emitted HLO text must parse back through XLA's HLO parser — this is
    exactly what ``HloModuleProto::from_text_file`` does on the Rust side
    (the text parser reassigns instruction ids; see aot.py docstring)."""
    from jax._src.lib import xla_client as xc

    man = _manifest(quick_dir)
    for entry in man["artifacts"]:
        text = open(os.path.join(quick_dir, entry["path"])).read()
        mod = xc._xla.hlo_module_from_text(text)
        proto = mod.as_serialized_hlo_module_proto()
        assert len(proto) > 0, entry["name"]


def test_stablehlo_roundtrip_matches_jit(quick_dir):
    """Execute the same lowering the artifacts come from through a standalone
    XLA client and compare against directly calling the jitted function —
    the numeric contract the Rust PJRT runtime relies on. (The CPU-side
    HLO-text load/execute itself is integration-tested from Rust.)"""
    from jax._src.lib import xla_client as xc
    from jaxlib import _jax

    man = _manifest(quick_dir)
    entry = next(a for a in man["artifacts"] if a["name"] == "logreg_cu_m64")

    key = jax.random.PRNGKey(7)
    m, t = entry["meta"]["m"], entry["meta"]["t"]
    s_, mb = entry["meta"]["s"], entry["meta"]["mb"]
    w, b = M.logreg_init(key, m, t)
    x = (jax.random.uniform(key, (s_, mb, m)) < 0.1).astype(jnp.float32)
    y = (jax.random.uniform(key, (s_, mb, t)) < 0.2).astype(jnp.float32)
    wgt = jnp.ones((s_, mb), jnp.float32)
    lr = jnp.float32(0.1)
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in (w, b, x, y, wgt, lr)]
    lowered = jax.jit(M.logreg_client_update).lower(*specs)

    client = xc.make_cpu_client()
    dl = _jax.DeviceList(tuple(client.devices()))
    exe = client.compile_and_load(str(lowered.compiler_ir("stablehlo")), dl)

    want = jax.jit(M.logreg_client_update)(w, b, x, y, wgt, lr)
    args = [np.asarray(a) for a in (w, b, x, y, wgt, lr)]
    bufs = [client.buffer_from_pyval(a) for a in args]
    results = exe.execute_sharded(bufs)
    got = [np.asarray(o[0]) for o in results.disassemble_into_single_device_arrays()]
    assert len(got) == len(want)
    for g, wv in zip(got, want):
        np.testing.assert_allclose(g, np.asarray(wv), rtol=1e-5, atol=1e-6)


def test_registry_full_grid_names_unique():
    reg = aot.build_registry(quick=False)
    names = [e["name"] for e in reg.entries]
    assert len(names) == len(set(names))
    # every figure/table has its variants present
    for needle in (
        "logreg_cu_m64",
        "logreg_eval_n8192",
        "mlp_cu_m200",
        "cnn_cu_m4",
        "cnn_eval",
        "tf_cu_v2048_h512",
        "tf_eval",
        "e2e_cu",
        "e2e_eval",
    ):
        assert needle in names, needle


def test_transformer_variant_grid_covers_all_schemes():
    reg = aot.build_registry(quick=False)
    tf = [e for e in reg.entries if e["model"] == "transformer"]
    mvs = {e["meta"]["mv"] for e in tf}
    dhs = {e["meta"]["dh"] for e in tf}
    assert aot.TF_VOCAB in mvs and aot.TF_FFN in dhs
    for a in aot.TF_ALPHAS:
        assert aot.TF_VOCAB // a in mvs
        assert aot.TF_FFN // a in dhs

"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes/dtypes; every property asserts allclose against the
oracle. This is the core correctness signal for the kernels that end up
inside the AOT artifacts the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import embed_lookup, gather_rows, matmul, pmatmul, scatter_add_rows
from compile.kernels.ref import gather_rows_ref, matmul_ref, scatter_add_rows_ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


FLOAT_DTYPES = [jnp.float32, jnp.bfloat16]


@st.composite
def gather_case(draw):
    k = draw(st.integers(1, 64))
    d = draw(st.integers(1, 48))
    m = draw(st.integers(1, 40))
    idx = draw(st.lists(st.integers(0, k - 1), min_size=m, max_size=m))
    seed = draw(st.integers(0, 2**31 - 1))
    dt = draw(st.sampled_from(FLOAT_DTYPES))
    return k, d, idx, seed, dt


@given(gather_case())
@settings(**SETTINGS)
def test_gather_rows_matches_ref(case):
    k, d, idx, seed, dt = case
    table = rand(seed, (k, d), dt)
    idx = jnp.array(idx, jnp.int32)
    got = gather_rows(table, idx)
    want = gather_rows_ref(table, idx)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=1e-6
    )


@given(gather_case())
@settings(**SETTINGS)
def test_scatter_add_matches_ref(case):
    k, d, idx, seed, dt = case
    b = len(idx)
    updates = rand(seed, (b, d), dt)
    idx = jnp.array(idx, jnp.int32)
    got = scatter_add_rows(updates, idx, k)
    want = scatter_add_rows_ref(updates, idx, k)
    assert got.shape == (k, d)
    # bf16 accumulation order can differ; loose tolerance for bf16.
    tol = 1e-5 if dt == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k), jnp.float32)
    y = rand(seed + 1, (k, n), jnp.float32)
    np.testing.assert_allclose(
        matmul(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_matmul_large_block_boundary(m, k, n, seed):
    """Shapes straddling the 128 tile boundary exercise the padding path."""
    m, k, n = m + 120, k + 120, n + 120
    x = rand(seed, (m, k), jnp.float32)
    y = rand(seed + 1, (k, n), jnp.float32)
    np.testing.assert_allclose(
        matmul(x, y), matmul_ref(x, y), rtol=1e-3, atol=1e-3
    )


def test_matmul_shape_errors():
    x = jnp.zeros((3, 4))
    with pytest.raises(ValueError):
        matmul(x, jnp.zeros((5, 2)))
    with pytest.raises(ValueError):
        gather_rows(jnp.zeros((3,)), jnp.zeros((2,), jnp.int32))
    with pytest.raises(ValueError):
        scatter_add_rows(jnp.zeros((3, 4)), jnp.zeros((2,), jnp.int32), 5)


def test_pmatmul_grads_match_dot_grads():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (9, 17))
    y = jax.random.normal(key, (17, 5))

    def f_pallas(x, y):
        return (pmatmul(x, y) ** 2).sum()

    def f_ref(x, y):
        return (x @ y) ** 2

    gx, gy = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(lambda x, y: f_ref(x, y).sum(), argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, gy_r, rtol=1e-4, atol=1e-4)


def test_embed_lookup_fwd_bwd():
    key = jax.random.PRNGKey(3)
    table = jax.random.normal(key, (11, 6))
    idx = jnp.array([1, 1, 4, 10, 0], jnp.int32)
    np.testing.assert_allclose(embed_lookup(table, idx), gather_rows_ref(table, idx))
    g = jax.random.normal(key, (5, 6))
    (gt,) = jax.vjp(lambda t: embed_lookup(t, idx), table)[1](g)
    np.testing.assert_allclose(
        gt, scatter_add_rows_ref(g, idx, 11), rtol=1e-6, atol=1e-6
    )


def test_kernels_under_jit():
    """The kernels must lower inside jit (the AOT path) with identical output."""
    key = jax.random.PRNGKey(4)
    table = jax.random.normal(key, (13, 7))
    idx = jnp.array([0, 12, 5], jnp.int32)
    np.testing.assert_allclose(
        jax.jit(gather_rows)(table, idx), gather_rows_ref(table, idx)
    )
    x = jax.random.normal(key, (31, 19))
    y = jax.random.normal(key, (19, 23))
    np.testing.assert_allclose(
        jax.jit(matmul)(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


def test_scatter_add_duplicate_keys_accumulate():
    updates = jnp.ones((4, 3))
    idx = jnp.array([2, 2, 2, 2], jnp.int32)
    out = scatter_add_rows(updates, idx, 5)
    np.testing.assert_allclose(out[2], 4.0 * jnp.ones(3))
    assert float(jnp.abs(out).sum()) == pytest.approx(12.0)

"""Layer-2 JAX models: the paper's four experiment model families.

Each family exposes

* ``<name>_init(key, ...)``          — parameter init (python tests only),
* ``<name>_client_update(...)``      — one local epoch of minibatch SGD on the
  *sliced* sub-model, returning the model delta ``initial - final`` (the
  paper's model-delta ClientUpdate, §2.2/§5.1). Minibatches are walked with
  ``lax.scan`` so the lowered HLO stays compact,
* ``<name>_eval(...)``               — full-model evaluation metrics.

Every function is pure and shape-static, so ``aot.py`` can lower one HLO
artifact per variant. Batches carry a per-example weight so the Rust side can
pad variable-size client datasets to the static batch shape (weight 0 ==
padding row; a fully-padded minibatch contributes a zero SGD step).

Dense projections run through the Pallas ``pmatmul`` kernel; the transformer
embedding runs through the Pallas gather/scatter pair (``embed_lookup``).
Model families:

1. ``logreg``      — multi-label one-vs-rest logistic regression (Stack
   Overflow tag prediction, paper §5.2). Slice = rows of W by word key.
2. ``mlp2nn``      — 2×200 hidden-layer MLP ("2NN" of McMahan et al., §5.3).
   Slice = hidden-1 neurons (couples W1 cols, b1, W2 rows).
3. ``cnn``         — 2-conv CNN (McMahan et al., §5.3). Slice = conv2
   filters (couples conv2 kernel out-channels, conv2 bias, dense1 rows).
4. ``transformer`` — next-word-prediction transformer (§5.4). Structured
   keys slice embedding rows + output columns; random keys slice FFN
   neurons.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import embed_lookup, pmatmul


def _sgd_epoch(loss_fn, params, batches, lr):
    """Scan minibatch SGD over ``batches``; return delta = initial - final."""

    grad_fn = jax.grad(loss_fn)

    def step(p, b):
        g = grad_fn(p, *b)
        return jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g), None

    final, _ = jax.lax.scan(step, params, batches)
    return jax.tree_util.tree_map(lambda w0, w1: w0 - w1, params, final)


def _wmean(per_example: jax.Array, wgt: jax.Array) -> jax.Array:
    """Weighted mean that is exactly 0 on an all-padding minibatch."""
    return (per_example * wgt).sum() / jnp.maximum(wgt.sum(), 1.0)


# ---------------------------------------------------------------------------
# 1. Multi-label logistic regression (tag prediction, §5.2)
# ---------------------------------------------------------------------------


def logreg_init(key, vocab: int, tags: int):
    kw, _ = jax.random.split(key)
    w = jax.random.normal(kw, (vocab, tags), jnp.float32) * 0.01
    b = jnp.zeros((tags,), jnp.float32)
    return w, b


def _logreg_loss(params, x, y, wgt):
    w, b = params
    logits = pmatmul(x, w) + b
    # Numerically-stable elementwise sigmoid BCE, summed over tags.
    per = jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return _wmean(per.sum(axis=-1), wgt)


def logreg_client_update(w, b, x, y, wgt, lr):
    """One epoch over [S, mb, ...] minibatches. Returns (dW, db)."""
    return _sgd_epoch(_logreg_loss, (w, b), (x, y, wgt), lr)


def logreg_eval(w, b, x, y, wgt):
    """Full-model eval: (loss_sum, recall@5_sum, weight_sum).

    Top-5 is computed by 5 iterated argmaxes rather than ``lax.top_k``: jax
    lowers top_k to the ``topk(..., largest=true)`` HLO instruction, which
    the xla_extension 0.5.1 text parser (the Rust runtime's loader) rejects.
    Argmax lowers to a plain reduce and round-trips cleanly.
    """
    logits = pmatmul(x, w) + b
    per = jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    loss_sum = (per.sum(axis=-1) * wgt).sum()
    rows = jnp.arange(logits.shape[0])
    scratch = logits
    in_top5 = jnp.zeros((logits.shape[0],), jnp.float32)
    for _ in range(5):
        idx = jnp.argmax(scratch, axis=-1)
        in_top5 = in_top5 + jnp.take_along_axis(y, idx[:, None], axis=-1)[:, 0]
        scratch = scratch.at[rows, idx].set(-jnp.inf)
    ntags = jnp.maximum(y.sum(axis=-1), 1.0)
    rec5 = in_top5 / ntags
    return loss_sum, (rec5 * wgt).sum(), wgt.sum()


# ---------------------------------------------------------------------------
# 2. 2NN MLP (EMNIST, §5.3)
# ---------------------------------------------------------------------------


def mlp2nn_init(key, m: int, hidden: int, classes: int, in_dim: int = 784):
    k1, k2, k3 = jax.random.split(key, 3)

    def glorot(k, fi, fo):
        return jax.random.normal(k, (fi, fo), jnp.float32) * jnp.sqrt(2.0 / (fi + fo))

    return (
        glorot(k1, in_dim, m),
        jnp.zeros((m,), jnp.float32),
        glorot(k2, m, hidden),
        jnp.zeros((hidden,), jnp.float32),
        glorot(k3, hidden, classes),
        jnp.zeros((classes,), jnp.float32),
    )


def _xent(logits, y, wgt):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return _wmean(-ll, wgt)


def _mlp_logits(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h1 = jax.nn.relu(pmatmul(x, w1) + b1)
    h2 = jax.nn.relu(pmatmul(h1, w2) + b2)
    return pmatmul(h2, w3) + b3


def _mlp_loss(params, x, y, wgt):
    return _xent(_mlp_logits(params, x), y, wgt)


def mlp2nn_client_update(w1, b1, w2, b2, w3, b3, x, y, wgt, lr):
    return _sgd_epoch(_mlp_loss, (w1, b1, w2, b2, w3, b3), (x, y, wgt), lr)


def mlp2nn_eval(w1, b1, w2, b2, w3, b3, x, y, wgt):
    """(loss_sum, weighted_correct, weight_sum)"""
    logits = _mlp_logits((w1, b1, w2, b2, w3, b3), x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
    correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
    return (-ll * wgt).sum(), (correct * wgt).sum(), wgt.sum()


# ---------------------------------------------------------------------------
# 3. CNN (EMNIST, §5.3)
# ---------------------------------------------------------------------------

_CONV_DN = ("NHWC", "HWIO", "NHWC")


def _conv(x, k):
    return jax.lax.conv_general_dilated(
        x, k, window_strides=(1, 1), padding="SAME", dimension_numbers=_CONV_DN
    )


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_init(key, m: int, classes: int, c1: int = 32, dense: int = 512):
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def he(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    return (
        he(k1, (5, 5, 1, c1), 25),
        jnp.zeros((c1,), jnp.float32),
        he(k2, (5, 5, c1, m), 25 * c1),
        jnp.zeros((m,), jnp.float32),
        he(k3, (7 * 7 * m, dense), 7 * 7 * m),
        jnp.zeros((dense,), jnp.float32),
        he(k4, (dense, classes), dense),
        jnp.zeros((classes,), jnp.float32),
    )


def _cnn_logits(params, x):
    k1, c1, k2, c2, w1, d1, w2, d2 = params
    h = _maxpool2(jax.nn.relu(_conv(x, k1) + c1))  # 28 -> 14
    h = _maxpool2(jax.nn.relu(_conv(h, k2) + c2))  # 14 -> 7
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(pmatmul(h, w1) + d1)
    return pmatmul(h, w2) + d2


def _cnn_loss(params, x, y, wgt):
    return _xent(_cnn_logits(params, x), y, wgt)


def cnn_client_update(k1, c1, k2, c2, w1, d1, w2, d2, x, y, wgt, lr):
    return _sgd_epoch(_cnn_loss, (k1, c1, k2, c2, w1, d1, w2, d2), (x, y, wgt), lr)


def cnn_eval(k1, c1, k2, c2, w1, d1, w2, d2, x, y, wgt):
    logits = _cnn_logits((k1, c1, k2, c2, w1, d1, w2, d2), x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
    correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
    return (-ll * wgt).sum(), (correct * wgt).sum(), wgt.sum()


# ---------------------------------------------------------------------------
# 4. Transformer LM (next-word prediction, §5.4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    """Static transformer shape configuration for one AOT variant.

    ``mv`` is the client-visible vocabulary (structured slice size; ``mv ==
    vocab`` means no structured selection) and ``dh`` the client-visible FFN
    width (random slice size; ``dh == ffn`` means no random selection).
    """

    mv: int  # sliced vocab size (embedding rows / output cols)
    d: int = 128  # model width
    seq: int = 20  # sequence length
    layers: int = 2
    heads: int = 4
    dh: int = 512  # sliced FFN hidden width

    def param_names(self) -> Sequence[str]:
        names = ["emb", "pos"]
        for i in range(self.layers):
            names += [
                f"l{i}_ln1_s",
                f"l{i}_ln1_b",
                f"l{i}_wq",
                f"l{i}_wk",
                f"l{i}_wv",
                f"l{i}_wo",
                f"l{i}_ln2_s",
                f"l{i}_ln2_b",
                f"l{i}_w1",
                f"l{i}_bf1",
                f"l{i}_w2",
                f"l{i}_bf2",
            ]
        names += ["lnf_s", "lnf_b", "wout", "bout"]
        return names

    def param_shapes(self) -> Sequence[tuple]:
        d, dh = self.d, self.dh
        shapes = [(self.mv, d), (self.seq, d)]
        for _ in range(self.layers):
            shapes += [
                (d,),
                (d,),
                (d, d),
                (d, d),
                (d, d),
                (d, d),
                (d,),
                (d,),
                (d, dh),
                (dh,),
                (dh, d),
                (d,),
            ]
        shapes += [(d,), (d,), (d, self.mv), (self.mv,)]
        return shapes


def transformer_init(key, cfg: TransformerCfg):
    params = []
    for name, shape in zip(cfg.param_names(), cfg.param_shapes()):
        key, sub = jax.random.split(key)
        if name.endswith(("_s",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b", "bf1", "bf2", "bout")) or name in ("pos",):
            if name == "pos":
                params.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
            else:
                params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return tuple(params)


def _layernorm(x, s, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * s + b


def _attention(h, wq, wk, wv, wo, heads):
    mb, L, d = h.shape
    hd = d // heads
    flat = h.reshape(-1, d)
    q = pmatmul(flat, wq).reshape(mb, L, heads, hd).transpose(0, 2, 1, 3)
    k = pmatmul(flat, wk).reshape(mb, L, heads, hd).transpose(0, 2, 1, 3)
    v = pmatmul(flat, wv).reshape(mb, L, heads, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(hd).astype(h.dtype)
    causal = jnp.tril(jnp.ones((L, L), bool))
    scores = jnp.where(causal[None, None], scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(-1, d)
    return pmatmul(out, wo).reshape(mb, L, d)


def _transformer_logits(params, x, cfg: TransformerCfg):
    """x: [mb, L] int32 of *local* (slice-relative) token ids."""
    emb, pos = params[0], params[1]
    mb, L = x.shape
    h = embed_lookup(emb, x.reshape(-1)).reshape(mb, L, cfg.d) + pos
    off = 2
    for _ in range(cfg.layers):
        ln1_s, ln1_b, wq, wk, wv, wo, ln2_s, ln2_b, w1, bf1, w2, bf2 = params[
            off : off + 12
        ]
        off += 12
        a = _attention(_layernorm(h, ln1_s, ln1_b), wq, wk, wv, wo, cfg.heads)
        h = h + a
        f = _layernorm(h, ln2_s, ln2_b).reshape(-1, cfg.d)
        f = jax.nn.relu(pmatmul(f, w1) + bf1)
        f = pmatmul(f, w2) + bf2
        h = h + f.reshape(mb, L, cfg.d)
    lnf_s, lnf_b, wout, bout = params[off : off + 4]
    h = _layernorm(h, lnf_s, lnf_b).reshape(-1, cfg.d)
    return (pmatmul(h, wout) + bout).reshape(mb, L, cfg.mv)


def make_transformer_loss(cfg: TransformerCfg):
    def loss(params, x, y, wgt):
        logits = _transformer_logits(params, x, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return _wmean(-ll.reshape(-1), wgt.reshape(-1))

    return loss


def make_transformer_client_update(cfg: TransformerCfg):
    """Returns fn(*params, x, y, wgt, lr) -> tuple of deltas."""
    loss = make_transformer_loss(cfg)
    nparams = len(cfg.param_names())

    def client_update(*args):
        params = tuple(args[:nparams])
        x, y, wgt, lr = args[nparams:]
        return _sgd_epoch(loss, params, (x, y, wgt), lr)

    return client_update


def make_transformer_eval(cfg: TransformerCfg):
    """Returns fn(*params, x, y, wgt) -> (loss_sum, correct, weight_sum)."""
    nparams = len(cfg.param_names())

    def evaluate(*args):
        params = tuple(args[:nparams])
        x, y, wgt = args[nparams:]
        logits = _transformer_logits(params, x, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, y[..., None].astype(jnp.int32), axis=-1)[..., 0]
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return (-ll * wgt).sum(), (correct * wgt).sum(), wgt.sum()

    return evaluate

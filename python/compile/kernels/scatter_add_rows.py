"""Pallas row scatter-add kernel: the deselection function φ on device.

``scatter_add_rows(updates, idx, num_rows)`` computes, for ``updates`` of
shape [b, d] and ``idx`` of shape [b]::

    out = zeros((num_rows, d));  out[idx[i], :] += updates[i, :]

This is FedSelect's deselect/aggregate primitive (paper §4, eq. 5) expressed
as a kernel, and doubles as the backward pass of the embedding lookup
(``embed.py``): the gradient w.r.t. an embedding table is exactly a
scatter-add of the per-token output gradients.

TPU mapping: the grid iterates over *update* rows. TPU grid iterations are
sequential per core, so read-modify-write accumulation into the output block
is race-free without atomics (unlike the CUDA ``atomicAdd`` formulation this
replaces). The accumulator block stays resident in VMEM across the grid.
``interpret=True`` for CPU-PJRT executability (see gather_rows.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scatter_kernel(idx_ref, upd_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    r = idx_ref[i]
    row = pl.load(out_ref, (pl.dslice(r, 1), slice(None)))
    pl.store(out_ref, (pl.dslice(r, 1), slice(None)), row + upd_ref[...])


def scatter_add_rows(updates: jax.Array, idx: jax.Array, num_rows: int) -> jax.Array:
    """Scatter-add ``updates`` ([b, d]) into a fresh [num_rows, d] array."""
    if updates.ndim != 2:
        raise ValueError(f"updates must be rank-2, got shape {updates.shape}")
    if idx.ndim != 1 or idx.shape[0] != updates.shape[0]:
        raise ValueError(
            f"idx shape {idx.shape} incompatible with updates {updates.shape}"
        )
    b, d = updates.shape
    return pl.pallas_call(
        _scatter_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_rows, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_rows, d), updates.dtype),
        interpret=True,
    )(idx.astype(jnp.int32), updates)

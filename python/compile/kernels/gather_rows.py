"""Pallas row-gather kernel: the select function ψ as an on-device gather.

``gather_rows(table, idx)`` returns ``out`` with ``out[i, :] = table[idx[i], :]``.

This is the Layer-1 realisation of FedSelect's ψ for row-keyed parameters
(embedding rows, logistic-regression weight rows): each select key picks one
row of a server-side table. The kernel is written TPU-first:

* the grid iterates over *output* rows (one select key per grid step), which
  on TPU is a sequential per-core schedule — no atomics or warp shuffles;
* the table is presented as a single VMEM-resident block (for the sliced
  sub-models this library feeds it, the table is the *client* slice, well
  under the ~16 MiB VMEM budget; the server-scale gather happens in Rust);
* ``interpret=True`` because the CPU PJRT plugin cannot execute Mosaic
  custom-calls; on a real TPU the same BlockSpec schedule applies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(idx_ref, table_ref, out_ref):
    i = pl.program_id(0)
    r = idx_ref[i]
    row = pl.load(table_ref, (pl.dslice(r, 1), slice(None)))
    out_ref[...] = row


def gather_rows(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather rows of ``table`` (shape [k, d]) at ``idx`` (shape [m], int32).

    Returns an array of shape [m, d] and ``table.dtype``.
    """
    if table.ndim != 2:
        raise ValueError(f"table must be rank-2, got shape {table.shape}")
    if idx.ndim != 1:
        raise ValueError(f"idx must be rank-1, got shape {idx.shape}")
    k, d = table.shape
    m = idx.shape[0]
    return pl.pallas_call(
        _gather_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), table.dtype),
        interpret=True,
    )(idx.astype(jnp.int32), table)

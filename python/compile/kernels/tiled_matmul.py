"""Pallas tiled matmul: the dense-projection hot spot of the L2 models.

Classic three-level blocked matmul with an accumulator block held across the
reduction dimension of the grid. Tile sizes are chosen per call so that the
three live blocks (x-tile, y-tile, out-tile) fit a VMEM budget and, when the
problem is large enough, are MXU-aligned multiples of 128. Under
``interpret=True`` this validates numerics/structure on CPU; DESIGN.md §7
estimates MXU utilization from the BlockSpec for the TPU target.

``matmul`` is the raw kernel; ``pmatmul`` wraps it in a ``jax.custom_vjp`` so
the L2 model code can differentiate straight through it (backward passes are
themselves tiled matmuls: dX = g·Yᵀ, dY = Xᵀ·g).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget for the three live tiles, in f32 elements. 3 * 128*128 * 4B is
# ~196 KiB — far under the ~16 MiB VMEM of a TPU core, leaving headroom for
# double-buffering the HBM->VMEM pipeline.
_MAX_TILE = 128


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def _pick_block(dim: int) -> int:
    """Largest MXU-friendly tile not overshooting the dimension too much."""
    if dim >= _MAX_TILE:
        return _MAX_TILE
    # Small dims: round up to a multiple of 8 (TPU sublane) to bound padding.
    return _ceil_to(dim, 8)


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Blocked ``x @ y`` for rank-2 operands via a Pallas kernel."""
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"bad matmul shapes {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    bm, bk, bn = _pick_block(m), _pick_block(k), _pick_block(n)
    pm, pk, pn = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, pm - m), (0, pk - k))) if (pm, pk) != (m, k) else x
    yp = jnp.pad(y, ((0, pk - k), (0, pn - n))) if (pk, pn) != (k, n) else y
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(pm // bm, pn // bn, pk // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), x.dtype),
        interpret=True,
    )(xp, yp)
    if (pm, pn) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def pmatmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable tiled matmul (fwd and bwd both run the Pallas kernel)."""
    return matmul(x, y)


def _pmatmul_fwd(x, y):
    return matmul(x, y), (x, y)


def _pmatmul_bwd(res, g):
    x, y = res
    return matmul(g, y.T), matmul(x.T, g)


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


@functools.partial(jax.jit, static_argnums=())
def _noop(x):  # pragma: no cover - keep module import side-effect free
    return x

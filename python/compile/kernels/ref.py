"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its oracle to float tolerance across the hypothesis shape/dtype sweep
in ``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """out[i, :] = table[idx[i], :]"""
    return jnp.take(table, idx.astype(jnp.int32), axis=0)


def scatter_add_rows_ref(
    updates: jax.Array, idx: jax.Array, num_rows: int
) -> jax.Array:
    """out[r, :] = sum over i with idx[i] == r of updates[i, :]"""
    return jax.ops.segment_sum(
        updates, idx.astype(jnp.int32), num_segments=num_rows
    ).astype(updates.dtype)


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)

"""Layer-1 Pallas kernels (build-time only; lowered into the AOT artifacts).

All kernels run with ``interpret=True`` so the emitted HLO executes on the
CPU PJRT plugin used by the Rust runtime; the BlockSpecs are written for the
TPU memory hierarchy (see DESIGN.md §Hardware-Adaptation).
"""

from .embed import embed_lookup
from .gather_rows import gather_rows
from .scatter_add_rows import scatter_add_rows
from .tiled_matmul import matmul, pmatmul

__all__ = [
    "embed_lookup",
    "gather_rows",
    "scatter_add_rows",
    "matmul",
    "pmatmul",
]

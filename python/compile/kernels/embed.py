"""Differentiable embedding lookup built from the gather/scatter kernel pair.

FedSelect's ψ (select) and φ (deselect) have an exact analogue inside the
model graph: the forward embedding lookup is a row-gather, and its vjp is a
row scatter-add. Pairing the two Pallas kernels through ``jax.custom_vjp``
means the transformer's embedding layer exercises both kernels in the single
AOT-compiled client-update executable.
"""

from __future__ import annotations

import jax

from .gather_rows import gather_rows
from .scatter_add_rows import scatter_add_rows


@jax.custom_vjp
def embed_lookup(table: jax.Array, idx: jax.Array) -> jax.Array:
    """``table[idx]`` with a scatter-add backward, both as Pallas kernels."""
    return gather_rows(table, idx)


def _embed_fwd(table, idx):
    return gather_rows(table, idx), (idx, table.shape[0])


def _embed_bwd(res, g):
    idx, num_rows = res
    return scatter_add_rows(g, idx, num_rows), None


embed_lookup.defvjp(_embed_fwd, _embed_bwd)

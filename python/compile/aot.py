"""AOT compiler: lower every (model, shape) variant to HLO text + manifest.

This is the single build-time entry point (``make artifacts``). It enumerates
the variant grid needed by the experiment harness (one artifact per static
shape configuration: FedSelect slice sizes are static per variant, the
learning rate is a runtime scalar input), lowers each jitted L2 function to
**HLO text**, and writes ``artifacts/manifest.json`` describing argument
order/shapes/dtypes for the Rust runtime.

HLO *text* (not a serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts [--only REGEX] [--quick]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32, I32 = "f32", "i32"

# ---------------------------------------------------------------------------
# Variant grid (scaled-down defaults; see DESIGN.md §4 for the mapping to the
# paper's scales — flags below extend toward paper scale).
# ---------------------------------------------------------------------------

LOGREG_TAGS = 50
LOGREG_CU_M = [64, 256, 1024, 2048, 8192]
LOGREG_EVAL_N = [512, 2048, 8192]
LOGREG_S, LOGREG_MB = 4, 16
LOGREG_EVAL_B = 256

MLP_HIDDEN, MLP_CLASSES = 200, 62
MLP_CU_M = [10, 50, 100, 200]
MLP_S, MLP_MB = 4, 16
MLP_EVAL_B = 256

CNN_CLASSES = 62
CNN_CU_M = [4, 8, 16, 32, 64]
CNN_S, CNN_MB = 2, 10
CNN_EVAL_B = 64

TF_VOCAB, TF_D, TF_SEQ, TF_LAYERS, TF_HEADS, TF_FFN = 2048, 128, 20, 2, 4, 512
TF_ALPHAS = [16, 8, 4, 2]  # denominators: mv = vocab/a, dh = ffn/a
TF_S, TF_MB = 2, 8
TF_EVAL_MB = 32

E2E_VOCAB, E2E_D, E2E_SEQ, E2E_LAYERS, E2E_HEADS, E2E_FFN = 65536, 256, 32, 4, 8, 1024
E2E_MV, E2E_DH = 1024, 256
E2E_S, E2E_MB = 2, 8
E2E_EVAL_MB = 4


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(s):
    return I32 if s.dtype == jnp.int32 else F32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Registry:
    def __init__(self):
        self.entries = []

    def add(self, name, fn, in_named, out_names, model, kind, meta):
        """in_named: list of (arg_name, ShapeDtypeStruct)."""
        self.entries.append(
            dict(
                name=name,
                fn=fn,
                in_named=in_named,
                out_names=out_names,
                model=model,
                kind=kind,
                meta=meta,
            )
        )


def build_registry(quick: bool = False) -> Registry:
    reg = Registry()

    # -- logreg ------------------------------------------------------------
    t = LOGREG_TAGS
    s_, mb = LOGREG_S, LOGREG_MB
    cu_ms = LOGREG_CU_M if not quick else LOGREG_CU_M[:2]
    for m in cu_ms:
        ins = [
            ("w", spec((m, t))),
            ("b", spec((t,))),
            ("x", spec((s_, mb, m))),
            ("y", spec((s_, mb, t))),
            ("wgt", spec((s_, mb))),
            ("lr", spec(())),
        ]
        reg.add(
            f"logreg_cu_m{m}",
            M.logreg_client_update,
            ins,
            ["dw", "db"],
            "logreg",
            "client_update",
            dict(m=m, t=t, s=s_, mb=mb),
        )
    eval_ns = LOGREG_EVAL_N if not quick else LOGREG_EVAL_N[:1]
    for n in eval_ns:
        ins = [
            ("w", spec((n, t))),
            ("b", spec((t,))),
            ("x", spec((LOGREG_EVAL_B, n))),
            ("y", spec((LOGREG_EVAL_B, t))),
            ("wgt", spec((LOGREG_EVAL_B,))),
        ]
        reg.add(
            f"logreg_eval_n{n}",
            M.logreg_eval,
            ins,
            ["loss_sum", "rec5_sum", "wsum"],
            "logreg",
            "eval",
            dict(n=n, t=t, b=LOGREG_EVAL_B),
        )

    # -- mlp2nn --------------------------------------------------------------
    h, c = MLP_HIDDEN, MLP_CLASSES
    s_, mb = MLP_S, MLP_MB
    cu_ms = MLP_CU_M if not quick else MLP_CU_M[:1]
    for m in cu_ms:
        ins = [
            ("w1", spec((784, m))),
            ("b1", spec((m,))),
            ("w2", spec((m, h))),
            ("b2", spec((h,))),
            ("w3", spec((h, c))),
            ("b3", spec((c,))),
            ("x", spec((s_, mb, 784))),
            ("y", spec((s_, mb), jnp.int32)),
            ("wgt", spec((s_, mb))),
            ("lr", spec(())),
        ]
        reg.add(
            f"mlp_cu_m{m}",
            M.mlp2nn_client_update,
            ins,
            ["dw1", "db1", "dw2", "db2", "dw3", "db3"],
            "mlp2nn",
            "client_update",
            dict(m=m, hidden=h, classes=c, s=s_, mb=mb),
        )
    ins = [
        ("w1", spec((784, h))),
        ("b1", spec((h,))),
        ("w2", spec((h, h))),
        ("b2", spec((h,))),
        ("w3", spec((h, c))),
        ("b3", spec((c,))),
        ("x", spec((MLP_EVAL_B, 784))),
        ("y", spec((MLP_EVAL_B,), jnp.int32)),
        ("wgt", spec((MLP_EVAL_B,))),
    ]
    reg.add(
        "mlp_eval",
        M.mlp2nn_eval,
        ins,
        ["loss_sum", "correct", "wsum"],
        "mlp2nn",
        "eval",
        dict(m=h, hidden=h, classes=c, b=MLP_EVAL_B),
    )

    # -- cnn ---------------------------------------------------------------
    if not quick:
        c = CNN_CLASSES
        s_, mb = CNN_S, CNN_MB
        for m in CNN_CU_M:
            ins = [
                ("k1", spec((5, 5, 1, 32))),
                ("c1", spec((32,))),
                ("k2", spec((5, 5, 32, m))),
                ("c2", spec((m,))),
                ("w1", spec((7 * 7 * m, 512))),
                ("d1", spec((512,))),
                ("w2", spec((512, c))),
                ("d2", spec((c,))),
                ("x", spec((s_, mb, 28, 28, 1))),
                ("y", spec((s_, mb), jnp.int32)),
                ("wgt", spec((s_, mb))),
                ("lr", spec(())),
            ]
            reg.add(
                f"cnn_cu_m{m}",
                M.cnn_client_update,
                ins,
                ["dk1", "dc1", "dk2", "dc2", "dw1", "dd1", "dw2", "dd2"],
                "cnn",
                "client_update",
                dict(m=m, classes=c, s=s_, mb=mb),
            )
        m = 64
        ins = [
            ("k1", spec((5, 5, 1, 32))),
            ("c1", spec((32,))),
            ("k2", spec((5, 5, 32, m))),
            ("c2", spec((m,))),
            ("w1", spec((7 * 7 * m, 512))),
            ("d1", spec((512,))),
            ("w2", spec((512, c))),
            ("d2", spec((c,))),
            ("x", spec((CNN_EVAL_B, 28, 28, 1))),
            ("y", spec((CNN_EVAL_B,), jnp.int32)),
            ("wgt", spec((CNN_EVAL_B,))),
        ]
        reg.add(
            "cnn_eval",
            M.cnn_eval,
            ins,
            ["loss_sum", "correct", "wsum"],
            "cnn",
            "eval",
            dict(m=m, classes=c, b=CNN_EVAL_B),
        )

    # -- transformer ---------------------------------------------------------
    def add_tf(name, cfg: M.TransformerCfg, s_, mb, vocab, kind, eval_mb=None):
        names = list(cfg.param_names())
        shapes = list(cfg.param_shapes())
        pins = [(n, spec(sh)) for n, sh in zip(names, shapes)]
        meta = dict(
            mv=cfg.mv,
            d=cfg.d,
            seq=cfg.seq,
            layers=cfg.layers,
            heads=cfg.heads,
            dh=cfg.dh,
            vocab=vocab,
            param_names=names,
        )
        if kind == "client_update":
            ins = pins + [
                ("x", spec((s_, mb, cfg.seq), jnp.int32)),
                ("y", spec((s_, mb, cfg.seq), jnp.int32)),
                ("wgt", spec((s_, mb, cfg.seq))),
                ("lr", spec(())),
            ]
            meta.update(s=s_, mb=mb)
            reg.add(
                name,
                M.make_transformer_client_update(cfg),
                ins,
                ["d_" + n for n in names],
                "transformer",
                kind,
                meta,
            )
        else:
            ins = pins + [
                ("x", spec((eval_mb, cfg.seq), jnp.int32)),
                ("y", spec((eval_mb, cfg.seq), jnp.int32)),
                ("wgt", spec((eval_mb, cfg.seq))),
            ]
            meta.update(b=eval_mb)
            reg.add(
                name,
                M.make_transformer_eval(cfg),
                ins,
                ["loss_sum", "correct", "wsum"],
                "transformer",
                kind,
                meta,
            )

    if not quick:
        combos = set()
        for a in TF_ALPHAS:
            combos.add((TF_VOCAB // a, TF_FFN))  # structured only
            combos.add((TF_VOCAB, TF_FFN // a))  # random only
            combos.add((TF_VOCAB // a, TF_FFN // a))  # mixed
        combos.add((TF_VOCAB, TF_FFN))  # no selection (baseline)
        for mv, dh in sorted(combos):
            cfg = M.TransformerCfg(
                mv=mv, d=TF_D, seq=TF_SEQ, layers=TF_LAYERS, heads=TF_HEADS, dh=dh
            )
            add_tf(
                f"tf_cu_v{mv}_h{dh}", cfg, TF_S, TF_MB, TF_VOCAB, "client_update"
            )
        full = M.TransformerCfg(
            mv=TF_VOCAB, d=TF_D, seq=TF_SEQ, layers=TF_LAYERS, heads=TF_HEADS, dh=TF_FFN
        )
        add_tf("tf_eval", full, 0, 0, TF_VOCAB, "eval", eval_mb=TF_EVAL_MB)

        # end-to-end driver variant (large server model, small client slice)
        e2e_cfg = M.TransformerCfg(
            mv=E2E_MV, d=E2E_D, seq=E2E_SEQ, layers=E2E_LAYERS, heads=E2E_HEADS, dh=E2E_DH
        )
        add_tf("e2e_cu", e2e_cfg, E2E_S, E2E_MB, E2E_VOCAB, "client_update")
        e2e_full = M.TransformerCfg(
            mv=E2E_VOCAB, d=E2E_D, seq=E2E_SEQ, layers=E2E_LAYERS, heads=E2E_HEADS, dh=E2E_FFN
        )
        add_tf("e2e_eval", e2e_full, 0, 0, E2E_VOCAB, "eval", eval_mb=E2E_EVAL_MB)

    return reg


def lower_entry(entry, out_dir):
    specs = [s for _, s in entry["in_named"]]
    lowered = jax.jit(entry["fn"]).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, entry["name"] + ".hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(entry["fn"], *specs)
    flat_out, _ = jax.tree_util.tree_flatten(out_avals)
    manifest_entry = dict(
        name=entry["name"],
        path=entry["name"] + ".hlo.txt",
        model=entry["model"],
        kind=entry["kind"],
        meta=entry["meta"],
        inputs=[
            dict(name=n, shape=list(s.shape), dtype=_dt(s))
            for n, s in entry["in_named"]
        ],
        outputs=[
            dict(name=n, shape=list(s.shape), dtype=_dt(s))
            for n, s in zip(entry["out_names"], flat_out)
        ],
        hlo_sha256=hashlib.sha256(text.encode()).hexdigest(),
    )
    return manifest_entry, len(text)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    ap.add_argument(
        "--quick", action="store_true", help="small subset (CI / python tests)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    reg = build_registry(quick=args.quick)
    entries = reg.entries
    if args.only:
        rx = re.compile(args.only)
        entries = [e for e in entries if rx.search(e["name"])]
    manifest = []
    t_start = time.time()
    for i, e in enumerate(entries):
        t0 = time.time()
        me, nchars = lower_entry(e, args.out_dir)
        manifest.append(me)
        print(
            f"[{i + 1}/{len(entries)}] {e['name']}: {nchars} chars "
            f"({time.time() - t0:.1f}s)",
            flush=True,
        )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(dict(version=1, artifacts=manifest), f, indent=1)
    print(f"wrote {len(manifest)} artifacts in {time.time() - t_start:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
